//! Metrics collection decoupled from policy and clock.

use crate::coordinator::metrics::{DispatchRecord, RunMetrics};
use crate::mem::MemStats;
use crate::sim::partitioned::Tile;
use crate::workloads::dnng::{DnnId, LayerId};

/// Passive listener attached to an [`Engine`](super::Engine) run.
///
/// Observers see the same callback stream regardless of which
/// [`Scheduler`](super::Scheduler) is driving, which is what makes
/// metrics comparable across policies: there is exactly one place that
/// turns events into numbers.
pub trait Observer {
    /// A layer was dispatched onto `tile` at cycle `t`.
    fn on_dispatch(&mut self, _t: u64, _dnn: DnnId, _layer: LayerId, _tile: Tile) {}

    /// A layer retired; `rec` is the full dispatch record (tile, start,
    /// end, activity).
    fn on_layer_complete(&mut self, _rec: &DispatchRecord) {}

    /// A running layer drained at a fold boundary because the scheduler
    /// preempted it; `rec` covers the drained segment (its `t_end` is the
    /// boundary cycle, its activity only the completed K-bands).  The
    /// layer is NOT done — it returns to the ready set and later segments
    /// (ending in a final `on_layer_complete`) finish it.
    /// `replayed_folds`/`wasted_cycles` are the partial-band work the
    /// remainder replays.  Only fires when a preempting policy runs.
    fn on_preempt(&mut self, _rec: &DispatchRecord, _replayed_folds: u64, _wasted_cycles: u64) {}

    /// A request's deadline cycle passed; `met` is whether its DNN had
    /// completed by then (completions at the same cycle count as met).
    fn on_deadline(&mut self, _dnn: DnnId, _t: u64, _met: bool) {}

    /// A layer retired under the shared memory hierarchy; `stats` is its
    /// memory-side record (stall cycles, words moved, refetches).  Only
    /// fires when `[mem]` is enabled, once per completed layer, right
    /// after [`Observer::on_layer_complete`].
    fn on_mem(&mut self, _dnn: DnnId, _tenant: &str, _stats: &MemStats) {}
}

/// `RunMetrics` *is* an observer: attach one to any engine run and the
/// familiar makespan / completion / dispatch-log / activity metrics fall
/// out — identically for every policy and every entry point (CLI `run`,
/// scenarios, sweeps).
impl Observer for RunMetrics {
    fn on_layer_complete(&mut self, rec: &DispatchRecord) {
        self.record_dispatch(rec.clone());
    }

    fn on_preempt(&mut self, rec: &DispatchRecord, replayed_folds: u64, wasted_cycles: u64) {
        self.record_preempt(rec.clone(), replayed_folds, wasted_cycles);
    }

    fn on_mem(&mut self, _dnn: DnnId, tenant: &str, stats: &MemStats) {
        self.record_mem(tenant, stats);
    }
}

/// No-op observer for callers that only want side effects of the run
/// (e.g. exercising a policy in a test).
#[derive(Debug, Clone, Copy, Default)]
pub struct NullObserver;

impl Observer for NullObserver {}
