//! The discrete-event engine: event queue + clock + allocation bookkeeping.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use super::event::Event;
use super::observer::Observer;
use super::queue::EventQueue;
use super::scheduler::{Checkpoint, LayerExec, RunningLayer, Scheduler, SystemState};
use crate::coordinator::metrics::{DispatchRecord, RunMetrics};
use crate::coordinator::partition::{AllocId, LaneManager, PartitionManager};
use crate::coordinator::queue::TaskQueue;
use crate::mem::{MemStats, MemSystem, MemUpdate};
use crate::sim::activity::Activity;
use crate::sim::dataflow::ArrayGeometry;
use crate::sim::partitioned::{LaneSpan, Tile};
use crate::workloads::dnng::{Dnn, DnnId, LayerId, WorkloadPool};

/// Whether [`Observer`] callbacks are batched through the engine's ring
/// and delivered at cycle-batch boundaries.  Opt out with
/// `MTSA_NO_OBS_RING` (any value) to fire each callback at its event, as
/// the pre-ring engine did — observers are passive (they cannot influence
/// the engine), so both modes produce the identical callback sequence;
/// the switch exists for A/B timing and bisecting.
pub fn obs_ring_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var_os("MTSA_NO_OBS_RING").is_none())
}

/// Whether the engine bulk-drains each same-cycle event batch from the
/// queue in one operation (see
/// [`EventQueue::pop_batch_into`](super::queue::EventQueue::pop_batch_into))
/// instead of popping and re-probing `next_time` per event.  FIFO order
/// within the batch is preserved exactly, so both modes process the
/// identical event sequence; opt out with `MTSA_NO_EVENT_COALESCE` (any
/// value) for A/B timing and bisecting.  Runs with the shared `[mem]`
/// hierarchy never take the bulk path regardless of the flag: a
/// bandwidth rescale can post new events *at the current cycle*
/// mid-batch, and those must interleave into the batch in key order.
pub fn event_coalesce_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var_os("MTSA_NO_EVENT_COALESCE").is_none())
}

/// A buffered observer callback: the `Copy` payload of one notification,
/// with the `DispatchRecord` (and its name `String` clones) built only at
/// delivery time, out of the event hot path.
#[derive(Debug, Clone, Copy)]
enum ObsEvent {
    Dispatch { t: u64, dnn: DnnId, layer: LayerId, tile: Tile },
    LayerComplete {
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        lanes: Option<LaneSpan>,
        t_start: u64,
        t_end: u64,
        activity: Activity,
    },
    Preempt {
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        t_start: u64,
        t_end: u64,
        activity: Activity,
        replayed_folds: u64,
        wasted_cycles: u64,
    },
    Deadline { dnn: DnnId, t: u64, met: bool },
    Mem { dnn: DnnId, stats: MemStats },
}

/// Execution details of an in-flight layer, keyed by its allocation.
#[derive(Debug, Clone, Copy)]
struct Pending {
    dnn: DnnId,
    layer: LayerId,
    t_start: u64,
    /// Currently scheduled completion (kept in sync with bandwidth
    /// rescales; `u64::MAX` for a starved strict-priority flight).
    t_end: u64,
    activity: Activity,
    /// The lane span this segment runs on when it was placed on the
    /// vector engine; `None` for systolic-array segments.
    lanes: Option<LaneSpan>,
    /// Armed preemption: the boundary cycle the segment drains at plus
    /// the checkpoint describing what it completes there.
    preempt: Option<(u64, Checkpoint)>,
}

/// Allocation-id offset marking vector-lane allocations: array ids come
/// from the array's [`PartitionManager`] (dense from 0), lane ids from
/// the [`LaneManager`]'s internal manager (also dense from 0) shifted by
/// this base so the two pools share the engine's single `pending` map,
/// event stream and memory arbiter without collision.
const LANE_ID_BASE: AllocId = 1 << 60;

/// The one simulation engine behind `mtsa run`, the scenario engine and
/// the sweep runner.
///
/// The engine owns the clock, the event queue, the DAG-aware
/// [`TaskQueue`] and the [`PartitionManager`]; a [`Scheduler`] owns
/// *policy* and an [`Observer`] owns *metrics*.  One run:
///
/// 1. seed [`Event::Arrival`] events from the pool (plus any
///    [`Event::Deadline`]s attached via [`Engine::with_deadlines`]);
/// 2. pop every event at the earliest pending cycle, retire completions
///    (free + merge columns, advance the task queue) and fire the
///    scheduler hooks;
/// 3. call [`Scheduler::plan`] once over the settled state and apply its
///    allocations at their exact proposed positions, pricing each via
///    [`Scheduler::exec`] and scheduling its completion;
/// 4. repeat until every layer has retired, then drain any remaining
///    deadline events (all met by construction).
///
/// Determinism: events are totally ordered (see [`Event`]), the scheduler
/// contract is deterministic, and the engine adds no randomness — a fixed
/// workload and policy reproduce byte-identical metrics anywhere.
///
/// The engine *owns* its pool (cloned at construction) and is steppable:
/// [`Engine::run`] is exactly [`Engine::start`] followed by
/// [`Engine::step`] until the queue drains.  The fleet tier drives the
/// step API directly, interleaving event processing with runtime
/// admissions ([`Engine::admit`]) and slot recycling
/// ([`Engine::release`]) so one long-lived engine can serve an unbounded
/// request stream in bounded memory.
pub struct Engine {
    pool: WorkloadPool,
    queue: TaskQueue,
    partitions: PartitionManager,
    events: EventQueue,
    pending: BTreeMap<AllocId, Pending>,
    /// `(dnn, absolute deadline cycle)` pairs to turn into events.
    deadlines: Vec<(DnnId, u64)>,
    /// Live runtime deadlines (`dnn → cycle`) armed via
    /// [`Engine::push_deadline`].  Under slot recycling a released DNN's
    /// still-queued Deadline event must not fire against the NEW tenant
    /// occupying the recycled id; once any runtime deadline exists, a
    /// Deadline event is real only while it matches this map exactly and
    /// every mismatch is a husk to skip.
    runtime_deadlines: BTreeMap<DnnId, u64>,
    /// True once [`Engine::push_deadline`] has ever been called — flips
    /// Deadline events into validate-against-the-map mode.  Kept separate
    /// from the map's emptiness so a husk arriving after its entry was
    /// removed is still recognized as a husk.
    runtime_deadline_mode: bool,
    /// Arrival events not yet fired (progress can still come from outside).
    arrivals_pending: usize,
    /// Consecutive wake-ups scheduled while nothing else could change the
    /// state (no layer in flight, no future arrival) and nothing was
    /// dispatched — the livelock detector for wake-only policies.
    idle_wakes: u32,
    /// The shared memory hierarchy (bandwidth arbiter + bank allocator),
    /// instantiated from [`Scheduler::mem_spec`] at the start of
    /// [`Engine::run`]; `None` keeps the isolated DRAM pricing.
    mem: Option<MemSystem>,
    /// The vector-lane pool, instantiated from
    /// [`Scheduler::vector_spec`] at the start of [`Engine::run`];
    /// `None` keeps the array-only machine (byte-identical behavior).
    lanes: Option<LaneManager>,
    /// Earliest pending [`Event::MemRescale`] cycle — dedup: every
    /// rescale recomputes the next release anyway, so one pending event
    /// (the earliest) suffices and later/duplicate requests are dropped.
    mem_release_at: Option<u64>,
    /// K rows completed per `(dnn, layer)` by preempted segments — the
    /// checkpoint ledger behind [`SystemState::k_done`].  Empty (and
    /// never touched) unless the scheduler preempts.
    progress: BTreeMap<(DnnId, LayerId), u64>,
    /// FIFO buffer of observer callbacks for the cycle batch in flight,
    /// drained (in order) once per batch — see [`obs_ring_enabled`].  The
    /// vector is reused across batches, so steady state allocates nothing.
    obs_ring: Vec<ObsEvent>,
    /// Pool slots freed by [`Engine::release`], reused (LIFO) by
    /// [`Engine::admit`] — the recycling that bounds pool/queue memory by
    /// the peak live-tenant count instead of the total arrival count.
    free_dnn_slots: Vec<DnnId>,
    /// Recycled buffer for the coalesced same-cycle event drain — see
    /// [`event_coalesce_enabled`].  Steady state allocates nothing.
    batch_buf: Vec<Event>,
    /// Recycled running-layer view handed to [`Scheduler::preempt`].
    preempt_scratch: Vec<RunningLayer>,
    now: u64,
}

/// How many consecutive unproductive wake-only rounds a policy may take
/// before the engine declares it livelocked.  Generous enough for any
/// real epoch/time-slice policy that defers ready work across a few
/// boundaries; a policy that spins past this is waiting on a condition
/// that can never occur (state is unchanged and nothing else is pending).
const MAX_IDLE_WAKES: u32 = 1_000;

impl Engine {
    /// An engine over a clone of `pool` on an array of the given geometry.
    pub fn new(pool: &WorkloadPool, geom: ArrayGeometry) -> Engine {
        Engine {
            pool: pool.clone(),
            queue: TaskQueue::new(pool),
            partitions: PartitionManager::new(geom),
            events: EventQueue::new(),
            pending: BTreeMap::new(),
            deadlines: Vec::new(),
            runtime_deadlines: BTreeMap::new(),
            runtime_deadline_mode: false,
            arrivals_pending: pool.dnns.len(),
            idle_wakes: 0,
            mem: None,
            lanes: None,
            mem_release_at: None,
            progress: BTreeMap::new(),
            obs_ring: Vec::new(),
            free_dnn_slots: Vec::new(),
            batch_buf: Vec::new(),
            preempt_scratch: Vec::new(),
            now: 0,
        }
    }

    /// Attach absolute QoS deadlines; each becomes an
    /// [`Event::Deadline`] reported to the scheduler and observer.
    pub fn with_deadlines(mut self, deadlines: Vec<(DnnId, u64)>) -> Engine {
        self.deadlines = deadlines;
        self
    }

    /// The engine clock (the cycle of the last processed event batch).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Cycle of the earliest pending event, if any.
    pub fn next_event_time(&self) -> Option<u64> {
        self.events.next_time()
    }

    /// True when every layer of `dnn` has retired.
    pub fn dnn_done(&self, dnn: DnnId) -> bool {
        self.queue.dnn_done(dnn)
    }

    /// The engine's (owned, possibly recycled) workload pool.
    pub fn pool(&self) -> &WorkloadPool {
        &self.pool
    }

    /// Admit a new DNN at absolute cycle `t` (not before the engine
    /// clock), reusing a slot freed by [`Engine::release`] when one is
    /// available; returns the id the DNN runs under.  Only call between
    /// [`Engine::step`]s — never from inside scheduler or observer hooks.
    pub fn admit(&mut self, dnn: Dnn, t: u64) -> DnnId {
        assert!(
            t >= self.now,
            "admission at cycle {t} is in the engine's past (now {})",
            self.now
        );
        dnn.validate();
        let d = dnn.arriving_at(t);
        let id = match self.free_dnn_slots.pop() {
            Some(slot) => {
                self.pool.dnns[slot] = d;
                self.queue.reset_slot(slot, &self.pool.dnns[slot]);
                slot
            }
            None => {
                self.pool.dnns.push(d);
                let id = self.pool.dnns.len() - 1;
                self.queue.push_slot(&self.pool.dnns[id]);
                id
            }
        };
        self.events.push(Event::Arrival { t, dnn: id });
        self.arrivals_pending += 1;
        self.idle_wakes = 0; // new work: the livelock detector restarts
        id
    }

    /// Arm a runtime QoS deadline for a live (admitted) DNN; it fires as
    /// an [`Event::Deadline`] exactly like [`Engine::with_deadlines`]
    /// entries do.  Unlike construction-time deadlines these are
    /// recycling-safe: releasing the DNN, or re-arming it at a different
    /// cycle, turns the already-queued event into a husk that is skipped,
    /// so a recycled slot never inherits its predecessor's verdict.  Not
    /// composable with [`Engine::with_deadlines`] on the same engine.
    pub fn push_deadline(&mut self, dnn: DnnId, t: u64) {
        assert!(
            t >= self.now,
            "deadline at cycle {t} is in the engine's past (now {})",
            self.now
        );
        assert!(
            self.deadlines.is_empty(),
            "push_deadline cannot be mixed with with_deadlines"
        );
        self.runtime_deadline_mode = true;
        self.runtime_deadlines.insert(dnn, t);
        self.events.push(Event::Deadline { t, dnn });
    }

    /// Retire a *finished* DNN's slot for reuse by a later
    /// [`Engine::admit`]: its progress-ledger entries drop and the
    /// scheduler's [`Scheduler::on_dnn_retired`] hook fires so policies
    /// can shed their per-id state.  Only call between [`Engine::step`]s,
    /// after the observer callbacks referencing this DNN have flushed.
    pub fn release(&mut self, dnn: DnnId, sched: &mut dyn Scheduler) {
        assert!(self.queue.dnn_done(dnn), "releasing unfinished dnn {dnn}");
        debug_assert!(!self.free_dnn_slots.contains(&dnn), "double release of dnn {dnn}");
        let stale: Vec<(DnnId, LayerId)> =
            self.progress.range((dnn, 0)..=(dnn, usize::MAX)).map(|(&k, _)| k).collect();
        for k in stale {
            self.progress.remove(&k);
        }
        // Any still-pending runtime deadline of this DNN becomes a husk
        // the moment the map entry drops (the queued event no longer
        // matches anything).
        self.runtime_deadlines.remove(&dnn);
        self.free_dnn_slots.push(dnn);
        sched.on_dnn_retired(dnn);
    }

    /// Convenience: run `pool` under `sched` and collect [`RunMetrics`].
    pub fn execute(
        pool: &WorkloadPool,
        geom: ArrayGeometry,
        sched: &mut dyn Scheduler,
    ) -> RunMetrics {
        let mut metrics = RunMetrics::default();
        Engine::new(pool, geom).run(sched, &mut metrics);
        metrics
    }

    /// Queue one observer callback for this cycle batch (or deliver it on
    /// the spot when the ring is opted out).
    fn emit(&mut self, obs: &mut dyn Observer, ev: ObsEvent) {
        if obs_ring_enabled() {
            self.obs_ring.push(ev);
        } else {
            Self::deliver(&self.pool, obs, ev);
        }
    }

    /// Deliver this batch's buffered callbacks, in emission order.
    fn flush_obs(&mut self, obs: &mut dyn Observer) {
        if self.obs_ring.is_empty() {
            return;
        }
        let mut buf = std::mem::take(&mut self.obs_ring);
        for ev in buf.drain(..) {
            Self::deliver(&self.pool, obs, ev);
        }
        self.obs_ring = buf; // keep the capacity for the next batch
    }

    fn deliver(pool: &WorkloadPool, obs: &mut dyn Observer, ev: ObsEvent) {
        match ev {
            ObsEvent::Dispatch { t, dnn, layer, tile } => obs.on_dispatch(t, dnn, layer, tile),
            ObsEvent::LayerComplete { dnn, layer, tile, lanes, t_start, t_end, activity } => {
                let rec = DispatchRecord {
                    dnn,
                    dnn_name: pool.dnns[dnn].name.clone(),
                    layer,
                    layer_name: pool.dnns[dnn].layers[layer].name.clone(),
                    tile,
                    lanes,
                    t_start,
                    t_end,
                    activity,
                };
                obs.on_layer_complete(&rec);
            }
            ObsEvent::Preempt {
                dnn,
                layer,
                tile,
                t_start,
                t_end,
                activity,
                replayed_folds,
                wasted_cycles,
            } => {
                let rec = DispatchRecord {
                    dnn,
                    dnn_name: pool.dnns[dnn].name.clone(),
                    layer,
                    layer_name: pool.dnns[dnn].layers[layer].name.clone(),
                    tile,
                    lanes: None, // lane segments are never preempted
                    t_start,
                    t_end,
                    activity,
                };
                obs.on_preempt(&rec, replayed_folds, wasted_cycles);
            }
            ObsEvent::Deadline { dnn, t, met } => obs.on_deadline(dnn, t, met),
            ObsEvent::Mem { dnn, stats } => obs.on_mem(dnn, &pool.dnns[dnn].name, &stats),
        }
    }

    fn state(&self) -> SystemState<'_> {
        SystemState {
            now: self.now,
            pool: &self.pool,
            queue: &self.queue,
            partitions: &self.partitions,
            lanes: self.lanes.as_ref(),
            mem: self.mem.as_ref().map(|m| m.feedback()),
            progress: &self.progress,
        }
    }

    /// Apply a memory-system rescale: re-post the corrected completions
    /// (their stale predecessors are skipped via the staleness check) and
    /// schedule the next early bandwidth release, if any.
    fn apply_mem_update(&mut self, upd: MemUpdate) {
        for (alloc, t) in upd.reposts {
            let p = self.pending.get_mut(&alloc).expect("repost for live alloc");
            p.t_end = t;
            // A rescale that moves this flight's completion invalidates
            // any armed preemption: its checkpoint was located on the old
            // dilation and would credit K-bands the slowed (or sped-up)
            // segment has not actually reached at the boundary.  Dropping
            // the arm turns the pending Preempt event into a stale husk;
            // a still-starved tenant re-triggers at a later decision
            // point against the corrected timing.
            p.preempt = None;
            let (dnn, layer) = (p.dnn, p.layer);
            self.events.push(Event::LayerComplete { t, dnn, layer, alloc });
        }
        if let Some(t) = upd.next_release {
            // One pending rescale is enough: if an earlier one is already
            // queued, it will recompute (and re-request) this release.
            let earlier_pending = match self.mem_release_at {
                Some(p) => p <= t,
                None => false,
            };
            if !earlier_pending {
                self.mem_release_at = Some(t);
                self.events.push(Event::MemRescale { t });
            }
        }
    }

    /// Run to completion.  Panics if the scheduler deadlocks (the pool is
    /// not done and no completion is in flight when the event queue
    /// drains) — a policy bug, not a recoverable condition.
    pub fn run(mut self, sched: &mut dyn Scheduler, obs: &mut dyn Observer) {
        self.start(sched);
        while self.step(sched, obs) {}
        assert!(
            self.queue.all_done(),
            "engine drained its event queue with {} layer(s) never scheduled \
             (policy `{}` deadlocked)",
            self.queue.remaining(),
            sched.name(),
        );
    }

    /// Seed the run: instantiate the memory system and post the pool's
    /// arrival events plus any attached deadlines.  Call exactly once,
    /// before the first [`Engine::step`].
    pub fn start(&mut self, sched: &mut dyn Scheduler) {
        self.mem = sched.mem_spec().map(MemSystem::new);
        self.lanes = sched.vector_spec().map(|v| LaneManager::new(v.lanes));
        for (di, d) in self.pool.dnns.iter().enumerate() {
            self.events.push(Event::Arrival { t: d.arrival_cycles, dnn: di });
        }
        for &(dnn, t) in &self.deadlines {
            self.events.push(Event::Deadline { t, dnn });
        }
    }

    /// Process one cycle batch: every event at the earliest pending
    /// cycle, one plan over the settled state, the preemption check, and
    /// the batched observer flush.  Returns `false` when there is nothing
    /// left to do — the event queue is empty, or every admitted layer has
    /// retired (remaining deadline events are then drained and reported
    /// met).  A `false` return is *resumable*: a later [`Engine::admit`]
    /// posts new work and stepping continues.
    pub fn step(&mut self, sched: &mut dyn Scheduler, obs: &mut dyn Observer) -> bool {
        // Process the whole batch of events at this cycle.
        let mut needs_plan = false;
        if event_coalesce_enabled() && self.mem.is_none() {
            // Bulk drain: without `[mem]`, handling an event never posts
            // another event at the *current* cycle (completions and
            // shrink remainders schedule at `now + cycles.max(1)`), so
            // the batch is closed the moment it is popped and one queue
            // operation replaces the pop/re-probe-per-event loop.
            let mut batch = std::mem::take(&mut self.batch_buf);
            batch.clear();
            let Some(now) = self.events.pop_batch_into(&mut batch) else {
                self.batch_buf = batch;
                return false;
            };
            debug_assert!(now >= self.now, "event time went backwards");
            self.now = now;
            for ev in batch.drain(..) {
                self.handle(ev, sched, obs, &mut needs_plan);
            }
            self.batch_buf = batch; // keep the capacity for the next batch
        } else {
            let Some(first) = self.events.pop() else { return false };
            let now = first.time();
            debug_assert!(now >= self.now, "event time went backwards");
            self.now = now;
            let mut next = Some(first);
            while let Some(ev) = next {
                self.handle(ev, sched, obs, &mut needs_plan);
                next = if self.events.next_time() == Some(now) {
                    self.events.pop()
                } else {
                    None
                };
            }
        }

        // One decision point over the settled state: plan dispatches
        // into the free space first, then offer the policy its
        // preemption check — starvation is judged against what the
        // plan actually left free, so a layer dispatched this very
        // cycle can itself become the victim (bounded to its first
        // fold boundary).
        if needs_plan && !self.queue.all_done() {
            self.dispatch(sched, obs);
            self.request_preemptions(sched);
        }

        // Deliver this batch's observer callbacks in one sweep.
        // Observers are passive, so deferring within the cycle cannot
        // change engine behavior, and FIFO delivery reproduces the
        // exact pre-ring callback sequence.
        self.flush_obs(obs);

        if self.queue.all_done() {
            // Only Deadline/Repartition (or stale Preempt) events can
            // remain; report the deadlines (all met — the work
            // finished first) and stop.  The clock is restored afterwards
            // so a resumable driver can still admit work between the
            // drained reports' (future) cycles and the real frontier.
            let resume_now = self.now;
            while let Some(ev) = self.events.pop() {
                if let Event::Deadline { t, dnn } = ev {
                    if self.runtime_deadline_mode {
                        if self.runtime_deadlines.get(&dnn) != Some(&t) {
                            continue; // husk: released or re-armed
                        }
                        self.runtime_deadlines.remove(&dnn);
                    }
                    self.now = t;
                    sched.on_deadline(&self.state(), dnn, true);
                    self.emit(obs, ObsEvent::Deadline { dnn, t, met: true });
                }
            }
            self.flush_obs(obs);
            self.now = resume_now;
            return false;
        }
        true
    }

    fn handle(
        &mut self,
        ev: Event,
        sched: &mut dyn Scheduler,
        obs: &mut dyn Observer,
        needs_plan: &mut bool,
    ) {
        match ev {
            Event::Arrival { dnn, .. } => {
                self.arrivals_pending -= 1;
                sched.on_arrival(&self.state(), dnn);
                *needs_plan = true;
            }
            Event::LayerComplete { t, dnn, layer, alloc } => {
                // A preemption may have evicted this alloc at an earlier
                // fold boundary (absence — alloc ids are never reused) or
                // shrunk it onto a re-priced remainder (t_end moved); the
                // completion is then a husk to skip.
                match self.pending.get(&alloc) {
                    Some(p) if p.t_end == t => {}
                    _ => return,
                }
                // Under the shared memory hierarchy a completion may have
                // been superseded by a bandwidth rescale; the re-posted
                // event is live and this one is a husk to skip.
                let mem_result = match self.mem.as_mut() {
                    Some(mem) => {
                        if mem.is_stale(alloc, t) {
                            return;
                        }
                        Some(mem.retire(t, alloc))
                    }
                    None => None,
                };
                let tile = if alloc >= LANE_ID_BASE {
                    let lanes =
                        self.lanes.as_mut().expect("lane completion without a lane pool");
                    let span = lanes
                        .span_of(alloc - LANE_ID_BASE)
                        .expect("completion of live lane alloc");
                    lanes.free(alloc - LANE_ID_BASE);
                    span.as_tile()
                } else {
                    let tile =
                        self.partitions.tile_of(alloc).expect("completion of live alloc");
                    self.partitions.free(alloc);
                    tile
                };
                self.queue.mark_done(dnn, layer);
                let pend = self.pending.remove(&alloc).expect("pending entry for live alloc");
                debug_assert_eq!((pend.dnn, pend.layer), (dnn, layer));
                sched.on_layer_complete(&self.state(), dnn, layer);
                self.emit(
                    obs,
                    ObsEvent::LayerComplete {
                        dnn,
                        layer,
                        tile,
                        lanes: pend.lanes,
                        t_start: pend.t_start,
                        t_end: t,
                        activity: pend.activity,
                    },
                );
                if let Some((stats, upd)) = mem_result {
                    self.emit(obs, ObsEvent::Mem { dnn, stats });
                    self.apply_mem_update(upd);
                }
                *needs_plan = true;
            }
            Event::Preempt { t, dnn, layer, alloc } => {
                // Stale if the segment already completed (a bandwidth
                // rescale can pull a completion before the boundary), if
                // the arm was invalidated by a rescale, or if a later
                // decision point re-armed the alloc at a different
                // boundary (then only the event matching the live arm is
                // real; earlier ones are husks).
                let Some(pend) = self.pending.get(&alloc).copied() else { return };
                let Some((t_b, ckpt)) = pend.preempt else { return };
                if t_b != t {
                    return;
                }
                debug_assert_eq!((pend.dnn, pend.layer), (dnn, layer));
                let tile = self.partitions.tile_of(alloc).expect("preempt of live alloc");
                // Credit the completed K-bands before re-pricing anything.
                if ckpt.k_advance > 0 {
                    *self.progress.entry((dnn, layer)).or_insert(0) += ckpt.k_advance;
                }
                self.emit(
                    obs,
                    ObsEvent::Preempt {
                        dnn,
                        layer,
                        tile,
                        t_start: pend.t_start,
                        t_end: t,
                        activity: ckpt.activity,
                        replayed_folds: ckpt.replayed_folds,
                        wasted_cycles: ckpt.wasted_cycles,
                    },
                );
                // Either way the segment's mem flight retires early:
                // banks release, surviving co-runners' shares grow.
                if let Some(mem) = self.mem.as_mut() {
                    let (stats, upd) = mem.preempt(t, alloc);
                    self.emit(obs, ObsEvent::Mem { dnn, stats });
                    self.apply_mem_update(upd);
                }
                match ckpt.keep {
                    Some(keep) => {
                        // Drain-and-reshape in place: the remainder keeps
                        // running on `keep`; the rest of the tile frees.
                        self.partitions.shrink(alloc, keep);
                        let coresident = self.partitions.allocated_count() as u64;
                        let exec = sched.exec(&self.state(), dnn, layer, keep, coresident);
                        self.schedule_segment(alloc, dnn, layer, keep, exec, None);
                    }
                    None => {
                        // Evict: the whole tile frees (and merges); the
                        // remainder re-enters the ready set with its
                        // progress and competes at the next plan.
                        self.pending.remove(&alloc);
                        self.partitions.free(alloc);
                        self.queue.mark_preempted(dnn, layer);
                    }
                }
                *needs_plan = true;
            }
            Event::Deadline { t, dnn } => {
                if self.runtime_deadline_mode {
                    if self.runtime_deadlines.get(&dnn) != Some(&t) {
                        return; // husk: slot released/recycled or re-armed
                    }
                    self.runtime_deadlines.remove(&dnn);
                }
                let met = self.queue.dnn_done(dnn);
                sched.on_deadline(&self.state(), dnn, met);
                self.emit(obs, ObsEvent::Deadline { dnn, t, met });
                // By default a deadline is a report, not a decision
                // point (it changes neither ready set nor tiling);
                // stateful SLA-aware policies opt into replanning via
                // `plan_on_deadline`.
                *needs_plan |= sched.plan_on_deadline();
            }
            Event::Repartition { .. } => {
                sched.on_repartition(&self.state());
                *needs_plan = true;
            }
            Event::MemRescale { .. } => {
                // Engine-internal: a transfer drained before its compute,
                // so the survivors' shares grow.  No scheduler hook, no
                // plan — and firing a stale one is a harmless no-op.
                if self.mem_release_at == Some(self.now) {
                    self.mem_release_at = None;
                }
                if let Some(mem) = self.mem.as_mut() {
                    let upd = mem.rescale(self.now);
                    self.apply_mem_update(upd);
                }
            }
        }
    }

    /// Offer the policy its preemption decision point: every in-flight
    /// layer not already draining toward a boundary is on the table
    /// (including layers dispatched this very cycle — their first fold
    /// boundary is still ahead).  A granted request arms the alloc and
    /// posts its [`Event::Preempt`] at the checkpoint's fold boundary;
    /// requests whose boundary would not beat the layer's own completion
    /// are dropped.
    /// Price and schedule a (re)dispatched layer segment at the current
    /// cycle: under `[mem]`, admit its remaining GEMM's traffic (the
    /// banked activity is what the observer bills) and take the
    /// arbiter's completion prediction (`u64::MAX` for a starved
    /// strict-priority flight — no event until a rescale frees it);
    /// otherwise schedule the exec-priced completion directly.  Shared
    /// by [`Engine::dispatch`] and the shrink-in-place preemption path.
    fn schedule_segment(
        &mut self,
        alloc: AllocId,
        dnn: DnnId,
        layer: LayerId,
        tile: Tile,
        exec: LayerExec,
        lanes: Option<LaneSpan>,
    ) {
        // A preempted remainder only moves its remaining GEMM's traffic
        // — the same discount the policy's `exec` priced compute with.
        let gemm = self.state().remaining_gemm(dnn, layer);
        if let Some(mem) = self.mem.as_mut() {
            // A lane segment streams its ideal traffic once (no fold
            // refetch, no banks); the arbiter prices the stream against
            // the co-runners so the vector engine contends for the same
            // DRAM bandwidth the array does.
            let (activity, upd) = match lanes {
                Some(_) => {
                    mem.admit_vector(self.now, alloc, dnn, gemm, exec.cycles, exec.activity)
                }
                None => mem.admit(self.now, alloc, dnn, gemm, tile, exec.cycles),
            };
            let t_end = upd
                .reposts
                .iter()
                .find(|&&(a2, _)| a2 == alloc)
                .map(|&(_, t)| t)
                .unwrap_or(u64::MAX);
            self.pending.insert(
                alloc,
                Pending { dnn, layer, t_start: self.now, t_end, activity, lanes, preempt: None },
            );
            self.apply_mem_update(upd);
        } else {
            let t_end = self.now + exec.cycles.max(1);
            let activity = exec.activity;
            self.pending.insert(
                alloc,
                Pending { dnn, layer, t_start: self.now, t_end, activity, lanes, preempt: None },
            );
            self.events.push(Event::LayerComplete { t: t_end, dnn, layer, alloc });
        }
    }

    fn request_preemptions(&mut self, sched: &mut dyn Scheduler) {
        if self.pending.is_empty() || !sched.preempts() {
            return;
        }
        let mut running = std::mem::take(&mut self.preempt_scratch);
        running.clear();
        running.extend(
            self.pending
                .iter()
                // Lane segments never preempt: the vector engine has no
                // fold boundaries to checkpoint at, and its segments are
                // short by construction (memory-bound layers).
                .filter(|(&alloc, p)| p.preempt.is_none() && alloc < LANE_ID_BASE)
                .map(|(&alloc, p)| RunningLayer {
                    alloc,
                    dnn: p.dnn,
                    layer: p.layer,
                    tile: self.partitions.tile_of(alloc).expect("live alloc has a tile"),
                    t_start: p.t_start,
                    t_end: p.t_end,
                }),
        );
        if running.is_empty() {
            self.preempt_scratch = running;
            return;
        }
        let mut requests = sched.preempt(&self.state(), &running);
        requests.sort_unstable();
        requests.dedup();
        for alloc in requests {
            let Some(run) = running.iter().find(|r| r.alloc == alloc) else { continue };
            let elapsed = self.now - run.t_start;
            let total = run.t_end.saturating_sub(run.t_start);
            let Some(ckpt) =
                sched.checkpoint(&self.state(), run.dnn, run.layer, run.tile, elapsed, total)
            else {
                continue;
            };
            let t_b = run.t_start.saturating_add(ckpt.boundary).max(self.now);
            if t_b >= run.t_end {
                continue; // the layer finishes first: let it drain whole
            }
            if let Some(p) = self.pending.get_mut(&alloc) {
                p.preempt = Some((t_b, ckpt));
            }
            self.events.push(Event::Preempt { t: t_b, dnn: run.dnn, layer: run.layer, alloc });
        }
        self.preempt_scratch = running; // keep the capacity for the next round
    }

    fn dispatch(&mut self, sched: &mut dyn Scheduler, obs: &mut dyn Observer) {
        let allocs = sched.plan(&self.state());
        if !allocs.is_empty() {
            self.idle_wakes = 0; // progress: the livelock detector restarts
        }
        for &a in &allocs {
            if let Some(span) = a.lanes {
                // Vector placement: the span carves from the lane pool
                // under its own id space; pricing comes from the
                // policy's `exec_vector` closed form.
                let id = {
                    let lanes = self.lanes.as_mut().unwrap_or_else(|| {
                        panic!(
                            "policy `{}` returned a lane allocation without a vector_spec",
                            sched.name()
                        )
                    });
                    let (id, got) = lanes.allocate_at(span).unwrap_or_else(|| {
                        panic!(
                            "policy `{}` allocated unavailable lanes {:?} at cycle {}",
                            sched.name(),
                            span,
                            self.now
                        )
                    });
                    debug_assert_eq!(got, span);
                    id
                };
                let alloc = LANE_ID_BASE + id;
                self.queue.mark_running(a.dnn, a.layer);
                let exec = sched.exec_vector(&self.state(), a.dnn, a.layer, span);
                let tile = span.as_tile();
                self.emit(
                    obs,
                    ObsEvent::Dispatch { t: self.now, dnn: a.dnn, layer: a.layer, tile },
                );
                self.schedule_segment(alloc, a.dnn, a.layer, tile, exec, Some(span));
                continue;
            }
            let (alloc, tile) = self.partitions.allocate_at(a.tile).unwrap_or_else(|| {
                panic!(
                    "policy `{}` allocated unavailable tile {:?} at cycle {}",
                    sched.name(),
                    a.tile,
                    self.now
                )
            });
            self.queue.mark_running(a.dnn, a.layer);
            let coresident = self.partitions.allocated_count() as u64;
            let exec = sched.exec(&self.state(), a.dnn, a.layer, tile, coresident);
            self.emit(obs, ObsEvent::Dispatch { t: self.now, dnn: a.dnn, layer: a.layer, tile });
            // Under [mem], `exec.cycles` is the compute path; the mem
            // system grants banks, re-prices the DRAM traffic under the
            // banked share and predicts the contended completion.
            self.schedule_segment(alloc, a.dnn, a.layer, tile, exec, None);
        }
        sched.recycle_plan(allocs);
        if let Some(dt) = sched.wake_after(&self.state()) {
            // Livelock detector: a wake-up scheduled while nothing else
            // can change the state (no layer in flight, no future
            // arrival) and this round dispatched nothing is unproductive.
            // A legitimate epoch policy deferring ready work to the next
            // boundary takes a handful of these at most; a policy that
            // strings [`MAX_IDLE_WAKES`] together is waiting on a
            // condition that can never occur, and honoring it forever
            // would livelock instead of hitting the deadlock panic `run`
            // promises for policy bugs.
            if self.pending.is_empty() && self.arrivals_pending == 0 {
                self.idle_wakes += 1;
                assert!(
                    self.idle_wakes <= MAX_IDLE_WAKES,
                    "policy `{}` took {} consecutive repartition wake-ups at cycle {} without \
                     dispatching, with no layer in flight and no future arrival (policy \
                     deadlocked on its own wake-ups)",
                    sched.name(),
                    self.idle_wakes,
                    self.now,
                );
            }
            let t = self.now.saturating_add(dt.max(1));
            self.events.push(Event::Repartition { t });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::partitioned::{tile_layer_timing, FeedPolicy, Tile};
    use crate::sim_core::{Allocation, LayerExec};
    use crate::workloads::dnng::{Dnn, Layer};
    use crate::workloads::shapes::{LayerKind, LayerShape};

    const GEOM: ArrayGeometry = ArrayGeometry { rows: 128, cols: 128 };

    fn pool(arrivals: &[u64]) -> WorkloadPool {
        let dnns = arrivals
            .iter()
            .enumerate()
            .map(|(i, &at)| {
                let layers = vec![
                    Layer::new("l0", LayerKind::Fc, LayerShape::fc(32, 64, 64)),
                    Layer::new("l1", LayerKind::Fc, LayerShape::fc(32, 64, 64)),
                ];
                Dnn::chain(&format!("d{i}"), layers).arriving_at(at)
            })
            .collect();
        WorkloadPool::new("t", dnns)
    }

    /// Minimal FIFO policy: the earliest ready (dnn, layer) takes the
    /// whole array; used to exercise the engine independently of the
    /// production policies.
    struct FullArrayFifo {
        arrivals_seen: usize,
        completions_seen: usize,
        repartitions_seen: usize,
        wake_once: bool,
    }

    impl FullArrayFifo {
        fn new() -> FullArrayFifo {
            FullArrayFifo {
                arrivals_seen: 0,
                completions_seen: 0,
                repartitions_seen: 0,
                wake_once: false,
            }
        }
    }

    impl Scheduler for FullArrayFifo {
        fn name(&self) -> &'static str {
            "fifo-test"
        }
        fn on_arrival(&mut self, _s: &SystemState<'_>, _dnn: DnnId) {
            self.arrivals_seen += 1;
        }
        fn on_layer_complete(&mut self, _s: &SystemState<'_>, _dnn: DnnId, _layer: LayerId) {
            self.completions_seen += 1;
        }
        fn on_repartition(&mut self, _s: &SystemState<'_>) {
            self.repartitions_seen += 1;
        }
        fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
            if !s.partitions.fully_free() {
                return Vec::new();
            }
            let ready = s.queue.ready_at(s.now);
            ready
                .iter()
                .min_by_key(|r| (r.dnn, r.layer))
                .map(|r| {
                    vec![Allocation::array(r.dnn, r.layer, Tile::full(GEOM))]
                })
                .unwrap_or_default()
        }
        fn exec(
            &self,
            s: &SystemState<'_>,
            dnn: DnnId,
            layer: LayerId,
            tile: Tile,
            _coresident: u64,
        ) -> LayerExec {
            let gemm = s.pool.dnns[dnn].layers[layer].shape.gemm();
            let t = tile_layer_timing(GEOM, gemm, tile, FeedPolicy::Independent, &Default::default());
            LayerExec { cycles: t.cycles, activity: t.activity }
        }
        fn wake_after(&mut self, _s: &SystemState<'_>) -> Option<u64> {
            if self.wake_once {
                None
            } else {
                self.wake_once = true;
                Some(10)
            }
        }
    }

    #[test]
    fn engine_runs_every_layer_once_and_fires_hooks() {
        let p = pool(&[0, 5_000]);
        let mut sched = FullArrayFifo::new();
        let m = Engine::execute(&p, GEOM, &mut sched);
        assert_eq!(m.dispatches.len(), 4);
        assert_eq!(sched.arrivals_seen, 2);
        assert_eq!(sched.completions_seen, 4);
        assert_eq!(sched.repartitions_seen, 1, "wake_after schedules a Repartition event");
        // FIFO on a full array: strictly sequential records.
        for w in m.dispatches.windows(2) {
            assert!(w[0].t_end <= w[1].t_start);
        }
        assert!(m.completion["d1"] > m.completion["d0"]);
    }

    #[test]
    fn deadline_events_report_met_and_missed() {
        #[derive(Default)]
        struct Tally(Vec<(DnnId, u64, bool)>);
        impl Observer for Tally {
            fn on_deadline(&mut self, dnn: DnnId, t: u64, met: bool) {
                self.0.push((dnn, t, met));
            }
        }
        let p = pool(&[0]);
        // One absurdly tight deadline (cycle 1: missed) and one generous
        // deadline far beyond the makespan (met, reported in the drain).
        let mut sched = FullArrayFifo::new();
        let mut tally = Tally::default();
        Engine::new(&p, GEOM)
            .with_deadlines(vec![(0, 1), (0, u64::MAX)])
            .run(&mut sched, &mut tally);
        assert_eq!(tally.0.len(), 2);
        assert_eq!(tally.0[0], (0, 1, false), "in-flight at cycle 1 => missed");
        assert_eq!(tally.0[1], (0, u64::MAX, true), "drained after completion => met");
    }

    #[test]
    fn runtime_deadlines_survive_slot_recycling_and_keep_the_clock_resumable() {
        #[derive(Default)]
        struct Tally(Vec<(DnnId, u64, bool)>);
        impl Observer for Tally {
            fn on_deadline(&mut self, dnn: DnnId, t: u64, met: bool) {
                self.0.push((dnn, t, met));
            }
        }
        let mk = |name: &str| {
            Dnn::chain(
                name,
                vec![Layer::new("l0", LayerKind::Fc, LayerShape::fc(32, 64, 64))],
            )
        };
        let mut sched = FullArrayFifo::new();
        let mut tally = Tally::default();
        let mut eng = Engine::new(&WorkloadPool::new("t", vec![]), GEOM);
        eng.start(&mut sched);

        // First tenant: a deadline far past its completion.  The drain
        // reports it met but must NOT advance the resumable clock to it.
        let a = eng.admit(mk("a"), 0);
        eng.push_deadline(a, 1_000_000_000);
        while eng.step(&mut sched, &mut tally) {}
        assert!(eng.dnn_done(a));
        assert_eq!(tally.0, vec![(a, 1_000_000_000, true)]);
        let frontier = eng.now();
        assert!(frontier < 1_000_000_000, "drain must restore the clock");
        eng.release(a, &mut sched);

        // Second tenant reuses the SAME slot; arm a far-future deadline
        // AFTER its work completes, then release — the queued event
        // outlives the tenant and becomes a husk.
        let b = eng.admit(mk("b"), frontier + 10);
        assert_eq!(b, a, "LIFO recycling reuses the slot");
        while eng.step(&mut sched, &mut tally) {}
        eng.push_deadline(b, eng.now() + 2_000_000);
        eng.release(b, &mut sched); // husk: deadline event still queued
        let c = eng.admit(mk("c"), eng.now() + 1);
        assert_eq!(c, b);
        while eng.step(&mut sched, &mut tally) {}
        eng.release(c, &mut sched);
        // b's orphaned deadline event must not have fired against c.
        assert_eq!(tally.0.len(), 1, "husk deadline skipped: {:?}", tally.0);
    }

    #[test]
    #[should_panic(expected = "deadlocked")]
    fn deadlocking_policy_panics() {
        struct Never;
        impl Scheduler for Never {
            fn name(&self) -> &'static str {
                "never"
            }
            fn plan(&mut self, _s: &SystemState<'_>) -> Vec<Allocation> {
                Vec::new()
            }
            fn exec(
                &self,
                _s: &SystemState<'_>,
                _d: DnnId,
                _l: LayerId,
                _tl: Tile,
                _c: u64,
            ) -> LayerExec {
                unreachable!()
            }
        }
        Engine::execute(&pool(&[0]), GEOM, &mut Never);
    }

    #[test]
    fn plan_on_deadline_makes_deadlines_decision_points() {
        // A stateful policy that defers all work until it has observed a
        // deadline verdict: with `plan_on_deadline` the release happens
        // AT the deadline cycle, not at the next unrelated event (there
        // is none here — without the opt-in this run would deadlock).
        struct DeferUntilDeadline {
            inner: FullArrayFifo,
            released: bool,
        }
        impl Scheduler for DeferUntilDeadline {
            fn name(&self) -> &'static str {
                "defer-until-deadline"
            }
            fn on_deadline(&mut self, _s: &SystemState<'_>, _dnn: DnnId, _met: bool) {
                self.released = true;
            }
            fn plan_on_deadline(&self) -> bool {
                true
            }
            fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
                if self.released {
                    self.inner.plan(s)
                } else {
                    Vec::new()
                }
            }
            fn exec(
                &self,
                s: &SystemState<'_>,
                dnn: DnnId,
                layer: LayerId,
                tile: Tile,
                coresident: u64,
            ) -> LayerExec {
                self.inner.exec(s, dnn, layer, tile, coresident)
            }
        }
        let p = pool(&[0]);
        let mut sched = DeferUntilDeadline { inner: FullArrayFifo::new(), released: false };
        let mut m = RunMetrics::default();
        Engine::new(&p, GEOM).with_deadlines(vec![(0, 5_000)]).run(&mut sched, &mut m);
        assert_eq!(m.dispatches.len(), 2);
        assert_eq!(m.dispatches[0].t_start, 5_000, "release takes effect at deadline time");
    }

    #[test]
    #[should_panic(expected = "wake-up")]
    fn wake_only_policy_cannot_livelock() {
        // A policy that dispatches nothing and keeps asking to be woken
        // up must eventually hit the livelock detector (after
        // MAX_IDLE_WAKES unproductive rounds), not spin forever.
        struct Spinner;
        impl Scheduler for Spinner {
            fn name(&self) -> &'static str {
                "spinner"
            }
            fn plan(&mut self, _s: &SystemState<'_>) -> Vec<Allocation> {
                Vec::new()
            }
            fn exec(
                &self,
                _s: &SystemState<'_>,
                _d: DnnId,
                _l: LayerId,
                _tl: Tile,
                _c: u64,
            ) -> LayerExec {
                unreachable!()
            }
            fn wake_after(&mut self, _s: &SystemState<'_>) -> Option<u64> {
                Some(100)
            }
        }
        Engine::execute(&pool(&[0]), GEOM, &mut Spinner);
    }

    #[test]
    #[should_panic(expected = "unavailable tile")]
    fn overlapping_allocation_panics() {
        struct DoubleBook;
        impl Scheduler for DoubleBook {
            fn name(&self) -> &'static str {
                "double-book"
            }
            fn plan(&mut self, s: &SystemState<'_>) -> Vec<Allocation> {
                // Propose the same columns for every ready layer.
                s.queue
                    .ready_at(s.now)
                    .iter()
                    .map(|r| Allocation::array(r.dnn, r.layer, Tile::full_height(GEOM, 0, 64)))
                    .collect()
            }
            fn exec(
                &self,
                _s: &SystemState<'_>,
                _d: DnnId,
                _l: LayerId,
                _tl: Tile,
                _c: u64,
            ) -> LayerExec {
                LayerExec { cycles: 100, activity: Activity::default() }
            }
        }
        Engine::execute(&pool(&[0, 0]), GEOM, &mut DoubleBook);
    }
}
