//! Engine events and their deterministic total order.

use crate::coordinator::partition::AllocId;
use crate::workloads::dnng::{DnnId, LayerId};

/// One discrete event in the simulated timeline.
///
/// Events at the same cycle are processed in the order
/// `Arrival < LayerComplete < Preempt < Deadline < Repartition` (ties
/// broken by `(dnn, layer)`), which encodes three invariants:
///
/// - arrivals have no side effect beyond scheduler hooks, so they may go
///   first;
/// - completions retire (free columns, mark layers done) before deadlines
///   are judged, so a request finishing exactly *at* its deadline counts
///   as met — the same strict `done > deadline` rule
///   [`Scenario::analyze`](crate::coordinator::scenario::Scenario::analyze)
///   applies post-hoc;
/// - the single [`Scheduler::plan`](super::Scheduler::plan) call per
///   timestamp sees the fully-settled state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A DNN's arrival cycle has been reached.
    Arrival { t: u64, dnn: DnnId },
    /// A dispatched layer drains; its partition is freed (and merged).
    LayerComplete { t: u64, dnn: DnnId, layer: LayerId, alloc: AllocId },
    /// A scheduler-requested preemption reaches the running layer's next
    /// fold boundary: the completed K-bands drain, the tile frees, and
    /// the remainder returns to the ready set carrying its progress (see
    /// [`Scheduler::preempt`](super::Scheduler::preempt) and
    /// `docs/preemption.md`).  Ordered with completions (a completion at
    /// the same cycle wins and turns the preemption into a stale husk).
    Preempt { t: u64, dnn: DnnId, layer: LayerId, alloc: AllocId },
    /// A request's absolute QoS deadline passes.
    Deadline { t: u64, dnn: DnnId },
    /// A scheduler-requested wake-up (see
    /// [`Scheduler::wake_after`](super::Scheduler::wake_after)) — the
    /// decision point that makes time-sliced repartitioning policies
    /// expressible without any new engine machinery.
    Repartition { t: u64 },
    /// An in-flight transfer drains *before* its compute, releasing its
    /// DRAM share early — the engine's [`MemSystem`](crate::mem::MemSystem)
    /// rescales the survivors here.  Engine-internal: no scheduler hook
    /// fires and no plan is taken; firing a stale one is a no-op.  Only
    /// posted when the `[mem]` hierarchy is enabled.
    MemRescale { t: u64 },
}

impl Event {
    /// The cycle this event fires at.
    pub fn time(&self) -> u64 {
        match *self {
            Event::Arrival { t, .. }
            | Event::LayerComplete { t, .. }
            | Event::Preempt { t, .. }
            | Event::Deadline { t, .. }
            | Event::Repartition { t }
            | Event::MemRescale { t } => t,
        }
    }

    /// Total-order key: `(time, kind rank, dnn, layer)`.
    fn key(&self) -> (u64, u8, DnnId, LayerId) {
        match *self {
            Event::Arrival { t, dnn } => (t, 0, dnn, 0),
            Event::LayerComplete { t, dnn, layer, .. } => (t, 1, dnn, layer),
            Event::Preempt { t, dnn, layer, .. } => (t, 2, dnn, layer),
            Event::Deadline { t, dnn } => (t, 3, dnn, 0),
            Event::Repartition { t } => (t, 4, 0, 0),
            Event::MemRescale { t } => (t, 5, 0, 0),
        }
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_time_then_kind_then_ids() {
        let arr = Event::Arrival { t: 10, dnn: 5 };
        let done = Event::LayerComplete { t: 10, dnn: 0, layer: 3, alloc: 7 };
        let dl = Event::Deadline { t: 10, dnn: 0 };
        let rp = Event::Repartition { t: 10 };
        let early = Event::Repartition { t: 9 };
        assert!(early < arr, "time dominates kind");
        assert!(arr < done, "arrivals before completions at the same cycle");
        assert!(done < dl, "completions retire before deadlines are judged");
        assert!(dl < rp);
        let pre = Event::Preempt { t: 10, dnn: 0, layer: 3, alloc: 7 };
        assert!(done < pre, "a same-cycle completion beats its preemption");
        assert!(pre < dl, "preemptions settle before deadlines are judged");
        let done_b = Event::LayerComplete { t: 10, dnn: 1, layer: 0, alloc: 8 };
        assert!(done < done_b, "completion ties break by (dnn, layer)");
        let mr = Event::MemRescale { t: 10 };
        assert!(rp < mr, "rescales settle after every same-cycle decision");
        assert!(Event::MemRescale { t: 9 } < arr);
    }

    #[test]
    fn time_accessor() {
        assert_eq!(Event::Arrival { t: 42, dnn: 0 }.time(), 42);
        assert_eq!(Event::Repartition { t: 7 }.time(), 7);
    }
}
