//! Bench harness (criterion replacement for the offline build).
//!
//! Bench targets are `[[bench]] harness = false` binaries; each calls
//! [`Bench::new`] and registers closures with [`Bench::measure`] for
//! timed micro-benchmarks, or prints figure tables directly.  Output goes
//! to stdout so `cargo bench | tee bench_output.txt` captures the paper
//! figures.

use std::time::{Duration, Instant};

use crate::util::stats::{fmt_ns, Summary};
use crate::util::tablefmt::Table;

/// Configuration for timed measurements.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_iters: 10,
            max_iters: 100_000,
        }
    }
}

/// A bench section with a results table.
pub struct Bench {
    name: String,
    opts: BenchOpts,
    table: Table,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        println!("\n=== bench: {name} ===");
        Bench {
            name: name.to_string(),
            opts: BenchOpts::default(),
            table: Table::new(&["case", "iters", "mean", "p50", "p99", "rsd"]),
        }
    }

    pub fn with_opts(mut self, opts: BenchOpts) -> Bench {
        self.opts = opts;
        self
    }

    /// Time `f` (called once per iteration) and record a row.
    /// Returns the summary for programmatic assertions.
    pub fn measure<F: FnMut()>(&mut self, case: &str, mut f: F) -> Summary {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.opts.warmup {
            f();
        }
        // Measure.  `min_iters` is honored unconditionally: a fast closure
        // must never be under-sampled just because `measure` elapsed (or
        // because `max_iters <= min_iters` made the cap win the race).
        let mut samples = Vec::new();
        let t1 = Instant::now();
        while samples.len() < self.opts.min_iters
            || (t1.elapsed() < self.opts.measure && samples.len() < self.opts.max_iters)
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        let summary = Summary::from_samples(&samples).expect("at least one sample");
        self.table.row(&[
            case.to_string(),
            summary.n.to_string(),
            fmt_ns(summary.mean),
            fmt_ns(summary.p50),
            fmt_ns(summary.p99),
            format!("{:.1}%", 100.0 * summary.rsd()),
        ]);
        summary
    }

    /// Print the accumulated table.
    pub fn finish(self) {
        if !self.table.is_empty() {
            println!("{}", self.table.render());
        }
        println!("=== end bench: {} ===", self.name);
    }
}

/// Print a figure/table section header (non-timed benches).
pub fn section(title: &str) {
    println!("\n--- {title} ---");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_sane_stats() {
        let mut b = Bench::new("test").with_opts(BenchOpts {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 1000,
        });
        let mut acc = 0u64;
        let s = b.measure("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.n >= 5);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
        b.finish();
    }

    #[test]
    fn max_iters_caps_runtime() {
        let mut b = Bench::new("cap").with_opts(BenchOpts {
            warmup: Duration::from_millis(1),
            measure: Duration::from_secs(60),
            min_iters: 1,
            max_iters: 50,
        });
        let s = b.measure("fast", || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.n, 50);
        b.finish();
    }

    #[test]
    fn min_iters_honored_when_measure_elapses_first() {
        // A zero measurement window used to starve fast closures down to a
        // single sample: the old loop condition let `max_iters` (or an
        // already-elapsed `measure`) short-circuit `min_iters`.
        let mut b = Bench::new("min").with_opts(BenchOpts {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(0),
            min_iters: 25,
            max_iters: 50,
        });
        let s = b.measure("fast", || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.n >= 25, "min_iters must be honored unconditionally, got {}", s.n);
        assert!(s.n <= 50, "max_iters still caps past min_iters, got {}", s.n);
        b.finish();
    }

    #[test]
    fn min_iters_wins_over_smaller_max_iters() {
        // When the two bounds conflict, the sampling floor wins — a summary
        // over too few samples is worse than a slightly longer run.
        let mut b = Bench::new("conflict").with_opts(BenchOpts {
            warmup: Duration::from_millis(0),
            measure: Duration::from_millis(0),
            min_iters: 10,
            max_iters: 3,
        });
        let s = b.measure("fast", || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.n, 10);
        b.finish();
    }
}
