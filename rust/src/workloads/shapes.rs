//! Layer shapes (paper Eq. 1), MAC-operation counts (Eq. 2), and the
//! conv→GEMM lowering.
//!
//! Each layer carries the 9-dimension tuple of the paper:
//! `shapes(l) = {M, N, C, R, S, H, W, P, Q}` where
//!
//! - `FW ∈ R^{M·C·R·S}` — filter weights (M output channels),
//! - `IFMap ∈ R^{N·C·H·W}` — input feature map (N batch),
//! - `OFMap ∈ R^{N·M·P·Q}` — output feature map.
//!
//! The weight-stationary systolic array executes every layer as a GEMM
//! `[Sr, K] × [K, M]` with `K = C·R·S` (weight rows mapped to PE rows) and
//! `Sr = N·P·Q` (the feed-stream length); fully-connected and recurrent
//! layers are the degenerate `R = S = H = W = P = Q = 1` case.

/// What kind of computation a layer performs (for reporting; the array
/// treats everything as a GEMM after lowering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    Conv,
    /// Fully-connected / projection (GEMM with R=S=1, spatial 1×1).
    Fc,
    /// Recurrent cell step (gates lowered to one fused GEMM).
    Recurrent,
    /// Attention projection / score GEMM.
    Attention,
    /// Embedding-style lookup lowered as a skinny GEMM.
    Embedding,
}

impl LayerKind {
    pub fn tag(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Fc => "fc",
            LayerKind::Recurrent => "rnn",
            LayerKind::Attention => "attn",
            LayerKind::Embedding => "embed",
        }
    }
}

/// The paper's 9-dimension layer shape (Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShape {
    /// Output channels (filter count).
    pub m: u64,
    /// Batch.
    pub n: u64,
    /// Input channels.
    pub c: u64,
    /// Filter height.
    pub r: u64,
    /// Filter width.
    pub s: u64,
    /// IFMap height.
    pub h: u64,
    /// IFMap width.
    pub w: u64,
    /// OFMap height.
    pub p: u64,
    /// OFMap width.
    pub q: u64,
}

/// GEMM dimensions after weight-stationary lowering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmDims {
    /// Feed-stream rows `Sr = N·P·Q`.
    pub sr: u64,
    /// Reduction depth `K = C·R·S` (stationary weight rows).
    pub k: u64,
    /// Output columns `M` (stationary weight columns).
    pub m: u64,
}

impl GemmDims {
    /// MACs of the lowered GEMM: `Sr · K · M`.
    pub fn macs(&self) -> u64 {
        self.sr * self.k * self.m
    }
}

impl LayerShape {
    /// Convolution layer from conventional parameters (square filter,
    /// `same`-style explicit output dims).
    pub fn conv(n: u64, c: u64, h: u64, w: u64, m: u64, r: u64, s: u64, stride: u64, pad: u64) -> LayerShape {
        assert!(stride > 0);
        let p = (h + 2 * pad).saturating_sub(r) / stride + 1;
        let q = (w + 2 * pad).saturating_sub(s) / stride + 1;
        LayerShape { m, n, c, r, s, h, w, p, q }
    }

    /// Fully-connected layer: `out = in[N, C] × W[C, M]`.
    pub fn fc(n: u64, c: u64, m: u64) -> LayerShape {
        LayerShape { m, n, c, r: 1, s: 1, h: 1, w: 1, p: 1, q: 1 }
    }

    /// Recurrent cell step over a sequence: the 4 LSTM gates (or 3 GRU
    /// gates) fused into one GEMM of `gates·hidden` output columns applied
    /// to `[seq·batch, input+hidden]`.
    pub fn recurrent(seq: u64, batch: u64, input: u64, hidden: u64, gates: u64) -> LayerShape {
        LayerShape {
            m: gates * hidden,
            n: seq * batch,
            c: input + hidden,
            r: 1,
            s: 1,
            h: 1,
            w: 1,
            p: 1,
            q: 1,
        }
    }

    /// Eq. 2: `Opr(l) = M · N · C · R · S · H · W`.
    ///
    /// The paper uses the product of FW and IFMap shapes as its layer-weight
    /// measure for sorting; we keep it verbatim for assignment-order
    /// fidelity (`Task_Assignment` sorts by this).
    pub fn opr(&self) -> u64 {
        self.m * self.n * self.c * self.r * self.s * self.h * self.w
    }

    /// True MAC count of the lowered GEMM (used for utilization/roofline):
    /// `N·P·Q · C·R·S · M`.
    pub fn macs(&self) -> u64 {
        self.gemm().macs()
    }

    /// Weight-stationary GEMM lowering.
    pub fn gemm(&self) -> GemmDims {
        GemmDims { sr: self.n * self.p * self.q, k: self.c * self.r * self.s, m: self.m }
    }

    /// Filter-weight tensor elements `M·C·R·S`.
    pub fn fw_elems(&self) -> u64 {
        self.m * self.c * self.r * self.s
    }

    /// IFMap tensor elements `N·C·H·W`.
    pub fn ifmap_elems(&self) -> u64 {
        self.n * self.c * self.h * self.w
    }

    /// OFMap tensor elements `N·M·P·Q`.
    pub fn ofmap_elems(&self) -> u64 {
        self.n * self.m * self.p * self.q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims() {
        // AlexNet conv1: 227x227x3, 96 filters 11x11 stride 4 -> 55x55
        let l = LayerShape::conv(1, 3, 227, 227, 96, 11, 11, 4, 0);
        assert_eq!((l.p, l.q), (55, 55));
        // 3x3 stride 1 pad 1 preserves spatial dims
        let l = LayerShape::conv(1, 64, 56, 56, 64, 3, 3, 1, 1);
        assert_eq!((l.p, l.q), (56, 56));
    }

    #[test]
    fn fc_is_degenerate_conv() {
        let l = LayerShape::fc(4, 4096, 1000);
        assert_eq!(l.gemm(), GemmDims { sr: 4, k: 4096, m: 1000 });
        assert_eq!(l.opr(), 4 * 4096 * 1000);
        assert_eq!(l.macs(), 4 * 4096 * 1000);
    }

    #[test]
    fn recurrent_fuses_gates() {
        // LSTM: 4 gates, hidden 256, input 128, seq 50, batch 1
        let l = LayerShape::recurrent(50, 1, 128, 256, 4);
        assert_eq!(l.gemm(), GemmDims { sr: 50, k: 384, m: 1024 });
    }

    #[test]
    fn opr_matches_eq2() {
        let l = LayerShape::conv(2, 3, 8, 8, 4, 3, 3, 1, 1);
        assert_eq!(l.opr(), 4 * 2 * 3 * 3 * 3 * 8 * 8);
    }

    #[test]
    fn gemm_macs_for_conv() {
        let l = LayerShape::conv(1, 3, 227, 227, 96, 11, 11, 4, 0);
        let g = l.gemm();
        assert_eq!(g.sr, 55 * 55);
        assert_eq!(g.k, 3 * 11 * 11);
        assert_eq!(g.m, 96);
        assert_eq!(l.macs(), 55 * 55 * 363 * 96);
    }

    #[test]
    fn tensor_footprints() {
        let l = LayerShape::conv(1, 3, 227, 227, 96, 11, 11, 4, 0);
        assert_eq!(l.fw_elems(), 96 * 3 * 11 * 11);
        assert_eq!(l.ifmap_elems(), 3 * 227 * 227);
        assert_eq!(l.ofmap_elems(), 96 * 55 * 55);
    }
}
