//! Layer shapes (paper Eq. 1), MAC-operation counts (Eq. 2), and the
//! conv→GEMM lowering.
//!
//! Each layer carries the 9-dimension tuple of the paper:
//! `shapes(l) = {M, N, C, R, S, H, W, P, Q}` where
//!
//! - `FW ∈ R^{M·C·R·S}` — filter weights (M output channels),
//! - `IFMap ∈ R^{N·C·H·W}` — input feature map (N batch),
//! - `OFMap ∈ R^{N·M·P·Q}` — output feature map.
//!
//! The weight-stationary systolic array executes every layer as a GEMM
//! `[Sr, K] × [K, M]` with `K = C·R·S` (weight rows mapped to PE rows) and
//! `Sr = N·P·Q` (the feed-stream length); fully-connected and recurrent
//! layers are the degenerate `R = S = H = W = P = Q = 1` case.

/// What kind of computation a layer performs (for reporting; the array
/// treats everything as a GEMM after lowering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    Conv,
    /// Fully-connected / projection (GEMM with R=S=1, spatial 1×1).
    Fc,
    /// Recurrent cell step (gates lowered to one fused GEMM).
    Recurrent,
    /// Attention projection / score GEMM.
    Attention,
    /// Embedding-style lookup lowered as a skinny GEMM.
    Embedding,
}

impl LayerKind {
    pub fn tag(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Fc => "fc",
            LayerKind::Recurrent => "rnn",
            LayerKind::Attention => "attn",
            LayerKind::Embedding => "embed",
        }
    }
}

/// The paper's 9-dimension layer shape (Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShape {
    /// Output channels (filter count).
    pub m: u64,
    /// Batch.
    pub n: u64,
    /// Input channels.
    pub c: u64,
    /// Filter height.
    pub r: u64,
    /// Filter width.
    pub s: u64,
    /// IFMap height.
    pub h: u64,
    /// IFMap width.
    pub w: u64,
    /// OFMap height.
    pub p: u64,
    /// OFMap width.
    pub q: u64,
}

/// GEMM dimensions after weight-stationary lowering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmDims {
    /// Feed-stream rows `Sr = N·P·Q`.
    pub sr: u64,
    /// Reduction depth `K = C·R·S` (stationary weight rows).
    pub k: u64,
    /// Output columns `M` (stationary weight columns).
    pub m: u64,
}

impl GemmDims {
    /// MACs of the lowered GEMM: `Sr · K · M`.
    pub fn macs(&self) -> u64 {
        self.sr * self.k * self.m
    }

    /// DRAM words the GEMM moves with *unbounded* SRAM: weights in once,
    /// IFMap streamed once, OFMap out once — the denominator of the
    /// arithmetic-intensity classification and the no-refetch traffic a
    /// vector engine streams (`crate::mem::ideal_words` delegates here).
    pub fn ideal_words(&self) -> u64 {
        self.k * self.m + self.sr * self.k + self.sr * self.m
    }

    /// Arithmetic intensity floor: MACs per ideal DRAM word, rounded
    /// down.  Pure integer arithmetic so classification is exact and
    /// portable across platforms.
    pub fn intensity(&self) -> u64 {
        self.macs() / self.ideal_words().max(1)
    }
}

/// Which resource class a layer's computation wants (systolic-vector,
/// PAPERS.md arXiv 2206.03060): high-arithmetic-intensity GEMMs earn
/// their array fold overheads back; low-intensity layers (LSTM steps at
/// small batch, embedding lookups, skinny projections) stream more words
/// than they multiply and waste array PEs no matter how they are tiled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Earns the systolic array: keep it on tile/column partitions.
    ComputeBound,
    /// Streaming-limited: a vector engine serves it with far fewer PEs.
    MemoryBound,
}

impl OpClass {
    pub fn tag(&self) -> &'static str {
        match self {
            OpClass::ComputeBound => "compute",
            OpClass::MemoryBound => "memory",
        }
    }
}

/// MACs-per-word threshold below which a GEMM is memory-bound.  Chosen at
/// the array-row scale (a 128-high fold re-uses each streamed word ~K/FK
/// times): layers that cannot re-use a word at least this often leave the
/// array idle waiting on the stream.
pub const INTENSITY_THRESHOLD: u64 = 64;

/// Classify a layer by op kind and arithmetic intensity — derivable from
/// the existing dims, no new workload metadata.  Embeddings are lookups
/// and always memory-bound; convolutions re-use every word `R·S`-fold
/// across spatial positions and always keep the array; everything else
/// (FC / recurrent / attention projections) goes by measured intensity.
pub fn op_class(kind: LayerKind, gemm: GemmDims) -> OpClass {
    match kind {
        LayerKind::Embedding => OpClass::MemoryBound,
        LayerKind::Conv => OpClass::ComputeBound,
        LayerKind::Fc | LayerKind::Recurrent | LayerKind::Attention => {
            if gemm.intensity() < INTENSITY_THRESHOLD {
                OpClass::MemoryBound
            } else {
                OpClass::ComputeBound
            }
        }
    }
}

impl LayerShape {
    /// Convolution layer from conventional parameters (square filter,
    /// `same`-style explicit output dims).
    pub fn conv(n: u64, c: u64, h: u64, w: u64, m: u64, r: u64, s: u64, stride: u64, pad: u64) -> LayerShape {
        assert!(stride > 0);
        let p = (h + 2 * pad).saturating_sub(r) / stride + 1;
        let q = (w + 2 * pad).saturating_sub(s) / stride + 1;
        LayerShape { m, n, c, r, s, h, w, p, q }
    }

    /// Fully-connected layer: `out = in[N, C] × W[C, M]`.
    pub fn fc(n: u64, c: u64, m: u64) -> LayerShape {
        LayerShape { m, n, c, r: 1, s: 1, h: 1, w: 1, p: 1, q: 1 }
    }

    /// Recurrent cell step over a sequence: the 4 LSTM gates (or 3 GRU
    /// gates) fused into one GEMM of `gates·hidden` output columns applied
    /// to `[seq·batch, input+hidden]`.
    pub fn recurrent(seq: u64, batch: u64, input: u64, hidden: u64, gates: u64) -> LayerShape {
        LayerShape {
            m: gates * hidden,
            n: seq * batch,
            c: input + hidden,
            r: 1,
            s: 1,
            h: 1,
            w: 1,
            p: 1,
            q: 1,
        }
    }

    /// Eq. 2: `Opr(l) = M · N · C · R · S · H · W`.
    ///
    /// The paper uses the product of FW and IFMap shapes as its layer-weight
    /// measure for sorting; we keep it verbatim for assignment-order
    /// fidelity (`Task_Assignment` sorts by this).
    pub fn opr(&self) -> u64 {
        self.m * self.n * self.c * self.r * self.s * self.h * self.w
    }

    /// True MAC count of the lowered GEMM (used for utilization/roofline):
    /// `N·P·Q · C·R·S · M`.
    pub fn macs(&self) -> u64 {
        self.gemm().macs()
    }

    /// Weight-stationary GEMM lowering.
    pub fn gemm(&self) -> GemmDims {
        GemmDims { sr: self.n * self.p * self.q, k: self.c * self.r * self.s, m: self.m }
    }

    /// Filter-weight tensor elements `M·C·R·S`.
    pub fn fw_elems(&self) -> u64 {
        self.m * self.c * self.r * self.s
    }

    /// IFMap tensor elements `N·C·H·W`.
    pub fn ifmap_elems(&self) -> u64 {
        self.n * self.c * self.h * self.w
    }

    /// OFMap tensor elements `N·M·P·Q`.
    pub fn ofmap_elems(&self) -> u64 {
        self.n * self.m * self.p * self.q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims() {
        // AlexNet conv1: 227x227x3, 96 filters 11x11 stride 4 -> 55x55
        let l = LayerShape::conv(1, 3, 227, 227, 96, 11, 11, 4, 0);
        assert_eq!((l.p, l.q), (55, 55));
        // 3x3 stride 1 pad 1 preserves spatial dims
        let l = LayerShape::conv(1, 64, 56, 56, 64, 3, 3, 1, 1);
        assert_eq!((l.p, l.q), (56, 56));
    }

    #[test]
    fn fc_is_degenerate_conv() {
        let l = LayerShape::fc(4, 4096, 1000);
        assert_eq!(l.gemm(), GemmDims { sr: 4, k: 4096, m: 1000 });
        assert_eq!(l.opr(), 4 * 4096 * 1000);
        assert_eq!(l.macs(), 4 * 4096 * 1000);
    }

    #[test]
    fn recurrent_fuses_gates() {
        // LSTM: 4 gates, hidden 256, input 128, seq 50, batch 1
        let l = LayerShape::recurrent(50, 1, 128, 256, 4);
        assert_eq!(l.gemm(), GemmDims { sr: 50, k: 384, m: 1024 });
    }

    #[test]
    fn opr_matches_eq2() {
        let l = LayerShape::conv(2, 3, 8, 8, 4, 3, 3, 1, 1);
        assert_eq!(l.opr(), 4 * 2 * 3 * 3 * 3 * 8 * 8);
    }

    #[test]
    fn gemm_macs_for_conv() {
        let l = LayerShape::conv(1, 3, 227, 227, 96, 11, 11, 4, 0);
        let g = l.gemm();
        assert_eq!(g.sr, 55 * 55);
        assert_eq!(g.k, 3 * 11 * 11);
        assert_eq!(g.m, 96);
        assert_eq!(l.macs(), 55 * 55 * 363 * 96);
    }

    #[test]
    fn ideal_words_and_intensity() {
        let g = GemmDims { sr: 10, k: 20, m: 30 };
        assert_eq!(g.ideal_words(), 20 * 30 + 10 * 20 + 10 * 30);
        assert_eq!(g.intensity(), g.macs() / g.ideal_words());
    }

    #[test]
    fn op_class_by_kind_and_intensity() {
        // ResNet-style conv: compute-bound by kind regardless of intensity.
        let conv = LayerShape::conv(1, 64, 56, 56, 64, 3, 3, 1, 1);
        assert_eq!(op_class(LayerKind::Conv, conv.gemm()), OpClass::ComputeBound);
        // GNMT-style LSTM step at batch 1: streams far more words than it
        // re-uses — memory-bound.
        let lstm = LayerShape::recurrent(50, 1, 512, 1024, 4);
        assert!(lstm.gemm().intensity() < INTENSITY_THRESHOLD);
        assert_eq!(op_class(LayerKind::Recurrent, lstm.gemm()), OpClass::MemoryBound);
        // The same cell at batch 128 amortizes the weight stream: compute-bound.
        let batched = LayerShape::recurrent(50, 128, 512, 1024, 4);
        assert_eq!(op_class(LayerKind::Recurrent, batched.gemm()), OpClass::ComputeBound);
        // Embeddings are lookups — always memory-bound, even when skinny
        // dims would pass the intensity bar.
        assert_eq!(op_class(LayerKind::Embedding, batched.gemm()), OpClass::MemoryBound);
        // Small-batch FC (AlexNet fc6 at N=4) is memory-bound.
        let fc = LayerShape::fc(4, 9216, 4096);
        assert_eq!(op_class(LayerKind::Fc, fc.gemm()), OpClass::MemoryBound);
        assert_eq!(OpClass::MemoryBound.tag(), "memory");
        assert_eq!(OpClass::ComputeBound.tag(), "compute");
    }

    #[test]
    fn tensor_footprints() {
        let l = LayerShape::conv(1, 3, 227, 227, 96, 11, 11, 4, 0);
        assert_eq!(l.fw_elems(), 96 * 3 * 11 * 11);
        assert_eq!(l.ifmap_elems(), 3 * 227 * 227);
        assert_eq!(l.ofmap_elems(), 96 * 55 * 55);
    }
}
