//! Melody-extraction LSTM (Park & Yoo, ICASSP 2017) — batch 1.
//!
//! Spectrogram frames (513-bin STFT) through two 256-hidden LSTM layers
//! and a pitch-class softmax head, over a 600-frame clip (a ~30 s song
//! section at ~20 fps — melody extraction runs whole clips, not single
//! frames).

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const FRAMES: u64 = 600;
const BINS: u64 = 513;
const HIDDEN: u64 = 256;
const PITCH_CLASSES: u64 = 722; // 60 semitones x 12 + unvoiced, as published

/// Build the melody LSTM at batch 1.
pub fn build() -> Dnn {
    let layers = vec![
        Layer::new("lstm1", LayerKind::Recurrent, LayerShape::recurrent(FRAMES, 1, BINS, HIDDEN, 4)),
        Layer::new("lstm2", LayerKind::Recurrent, LayerShape::recurrent(FRAMES, 1, HIDDEN, HIDDEN, 4)),
        Layer::new("pitch_fc", LayerKind::Fc, LayerShape::fc(FRAMES, HIDDEN, PITCH_CLASSES)),
    ];
    Dnn::chain("MelodyLSTM", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        assert_eq!(build().layers.len(), 3);
    }

    #[test]
    fn gate_dims() {
        let d = build();
        assert_eq!(d.layers[0].shape.gemm().k, BINS + HIDDEN);
        assert_eq!(d.layers[0].shape.gemm().m, 4 * HIDDEN);
    }

    #[test]
    fn light_but_not_trivial() {
        let macs = build().total_macs() as f64;
        assert!((5e8..2e9).contains(&macs), "got {macs}");
    }
}
