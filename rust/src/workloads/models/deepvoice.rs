//! Deep Voice text-to-speech (Arık et al., ICML 2017) — batch 1.
//!
//! The inference path of the grapheme-to-phoneme + duration + F0 +
//! vocoder-conditioning stack: small GRU layers plus skinny conv/FC
//! conditioning layers over a 40-phoneme utterance.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const PHONEMES: u64 = 200;
const HIDDEN: u64 = 256;

/// Build Deep Voice (inference conditioning stack) at batch 1.
pub fn build() -> Dnn {
    let layers = vec![
        Layer::new("g2p_embed", LayerKind::Embedding, LayerShape::fc(PHONEMES, 64, HIDDEN)),
        // Grapheme-to-phoneme: bidirectional GRU encoder + GRU decoder.
        Layer::new("g2p_enc_fwd", LayerKind::Recurrent, LayerShape::recurrent(PHONEMES, 1, HIDDEN, HIDDEN / 2, 3)),
        Layer::new("g2p_enc_bwd", LayerKind::Recurrent, LayerShape::recurrent(PHONEMES, 1, HIDDEN, HIDDEN / 2, 3)),
        Layer::new("g2p_dec", LayerKind::Recurrent, LayerShape::recurrent(PHONEMES, 1, HIDDEN, HIDDEN, 3)),
        // Duration prediction MLP.
        Layer::new("dur_fc1", LayerKind::Fc, LayerShape::fc(PHONEMES, HIDDEN, 256)),
        Layer::new("dur_fc2", LayerKind::Fc, LayerShape::fc(PHONEMES, 256, 1)),
        // F0 prediction GRU + head.
        Layer::new("f0_gru", LayerKind::Recurrent, LayerShape::recurrent(PHONEMES, 1, HIDDEN, 128, 3)),
        Layer::new("f0_fc", LayerKind::Fc, LayerShape::fc(PHONEMES, 128, 1)),
        // Vocoder conditioning projection.
        Layer::new("cond_fc", LayerKind::Fc, LayerShape::fc(PHONEMES, HIDDEN, 512)),
    ];
    Dnn::chain("DeepVoice", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        assert_eq!(build().layers.len(), 9);
    }

    #[test]
    fn gru_uses_three_gates() {
        let d = build();
        let dec = d.layers.iter().find(|l| l.name == "g2p_dec").unwrap();
        assert_eq!(dec.shape.gemm().m, 3 * HIDDEN);
    }

    #[test]
    fn is_light() {
        let macs = build().total_macs() as f64;
        assert!((5e7..5e8).contains(&macs), "got {macs}");
    }
}
