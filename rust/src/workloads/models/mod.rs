//! The 12-network zoo of the paper's Table 1.
//!
//! Layer dimension tables are transcribed from the published architectures
//! (the paper gives only model names; shapes are public facts of the
//! networks).  Two groups, as in Table 1:
//!
//! **Heavy (multi-domain)**: AlexNet, ResNet-50, GoogLeNet, SA_CNN,
//! SA_LSTM, NCF, AlphaGoZero, Transformer.
//!
//! **Light (RNN)**: Melody LSTM, Google Translate (GNMT), Deep Voice,
//! Handwriting LSTM.
//!
//! All models are inference-shaped at batch 1 (except NCF, which serves a
//! recommendation batch — a single-user scoring pass is a degenerate
//! 1-MAC GEMM that no accelerator study runs).  Substitution notes (e.g.
//! the reduced AlphaGoZero) are in each module's doc comment and DESIGN.md.

pub mod alexnet;
pub mod alphagozero;
pub mod deepvoice;
pub mod gnmt;
pub mod googlenet;
pub mod handwriting_lstm;
pub mod melody_lstm;
pub mod ncf;
pub mod resnet50;
pub mod sa_cnn;
pub mod sa_lstm;
pub mod transformer;

use super::dnng::{Dnn, WorkloadPool};

/// Table 1 metadata for one zoo entry.
#[derive(Debug, Clone, Copy)]
pub struct ZooEntry {
    pub name: &'static str,
    pub domain: &'static str,
    pub group: Group,
    pub build: fn() -> Dnn,
}

/// Workload group (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Group {
    /// Multi-domain, heavy-load.
    Heavy,
    /// RNN, light-load.
    Light,
}

impl Group {
    pub fn tag(&self) -> &'static str {
        match self {
            Group::Heavy => "heavy/multi-domain",
            Group::Light => "light/RNN",
        }
    }
}

/// The full Table 1 registry, paper order.
pub const ZOO: &[ZooEntry] = &[
    ZooEntry { name: "AlexNet", domain: "Image classification", group: Group::Heavy, build: alexnet::build },
    ZooEntry { name: "ResNet50", domain: "Image classification", group: Group::Heavy, build: resnet50::build },
    ZooEntry { name: "GoogleNet", domain: "Image classification", group: Group::Heavy, build: googlenet::build },
    ZooEntry { name: "SA_CNN", domain: "Sentiment analysis", group: Group::Heavy, build: sa_cnn::build },
    ZooEntry { name: "SA_LSTM", domain: "Sentiment analysis", group: Group::Heavy, build: sa_lstm::build },
    ZooEntry { name: "NCF", domain: "Recommendation system", group: Group::Heavy, build: ncf::build },
    ZooEntry { name: "AlphaGoZero", domain: "Intelligent search", group: Group::Heavy, build: alphagozero::build },
    ZooEntry { name: "Transformer", domain: "Natural language processing", group: Group::Heavy, build: transformer::build },
    ZooEntry { name: "MelodyLSTM", domain: "Melody extraction", group: Group::Light, build: melody_lstm::build },
    ZooEntry { name: "GoogleTranslate", domain: "Language translation", group: Group::Light, build: gnmt::build },
    ZooEntry { name: "DeepVoice", domain: "Text to speech", group: Group::Light, build: deepvoice::build },
    ZooEntry { name: "HandwritingLSTM", domain: "Handwriting recognition", group: Group::Light, build: handwriting_lstm::build },
];

/// Build the heavy (multi-domain) workload pool — Fig. 9(a)(c)(e).
///
/// All DNNs are submitted together (arrival 0), matching the paper's
/// "pool of n DNNs in the task queue" setup.
pub fn heavy_pool() -> WorkloadPool {
    WorkloadPool::new(
        "multi-domain (heavy)",
        ZOO.iter().filter(|e| e.group == Group::Heavy).map(|e| (e.build)()).collect(),
    )
}

/// Build the light (RNN) workload pool — Fig. 9(b)(d)(f).
pub fn light_pool() -> WorkloadPool {
    WorkloadPool::new(
        "RNN (light)",
        ZOO.iter().filter(|e| e.group == Group::Light).map(|e| (e.build)()).collect(),
    )
}

/// Look up a zoo entry by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<&'static ZooEntry> {
    let lower = name.to_lowercase();
    ZOO.iter().find(|e| e.name.to_lowercase() == lower)
}

/// Resolve a workload-mix spec: `"heavy"`, `"light"`, or comma-separated
/// zoo model names.  The error names the exact offending model so a typo
/// in a long list is pinpointed.  Shared by `mtsa run`, `mtsa sweep` and
/// the sweep library.
pub fn by_spec(spec: &str) -> Result<WorkloadPool, String> {
    match spec {
        "heavy" => Ok(heavy_pool()),
        "light" => Ok(light_pool()),
        list => {
            if list.trim().is_empty() {
                return Err("empty pool spec".to_string());
            }
            let mut dnns = Vec::new();
            for name in list.split(',') {
                let name = name.trim();
                let entry = by_name(name)
                    .ok_or_else(|| format!("unknown model {name:?} (see `mtsa zoo`)"))?;
                dnns.push((entry.build)());
            }
            Ok(WorkloadPool::new(spec, dnns))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_networks_in_two_groups() {
        assert_eq!(ZOO.len(), 12);
        assert_eq!(ZOO.iter().filter(|e| e.group == Group::Heavy).count(), 8);
        assert_eq!(ZOO.iter().filter(|e| e.group == Group::Light).count(), 4);
    }

    #[test]
    fn every_network_builds_and_validates() {
        for e in ZOO {
            let dnn = (e.build)();
            dnn.validate();
            assert!(!dnn.layers.is_empty(), "{} empty", e.name);
            for l in &dnn.layers {
                let g = l.shape.gemm();
                assert!(g.sr > 0 && g.k > 0 && g.m > 0, "{}/{} has a zero GEMM dim", e.name, l.name);
            }
        }
    }

    #[test]
    fn group_total_macs_ordering() {
        // The heavy pool must be substantially heavier than the light pool —
        // the premise of the paper's two-group evaluation.
        let heavy = heavy_pool().total_macs() as f64;
        let light = light_pool().total_macs() as f64;
        assert!(
            heavy > 1.5 * light,
            "heavy pool ({heavy}) should outweigh light pool ({light})"
        );
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("alexnet").is_some());
        assert!(by_name("AlexNet").is_some());
        assert!(by_name("GoogleTranslate").is_some());
        assert!(by_name("nope").is_none());
    }
}
