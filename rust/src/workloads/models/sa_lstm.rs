//! Regional CNN-LSTM for dimensional sentiment analysis (Wang et al.,
//! ACL 2016) — batch 1.
//!
//! Regional CNN feature extraction over a 64-token, 300-d embedded
//! sentence followed by a 128-hidden LSTM across regions and a valence/
//! arousal regression head.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const SEQ: u64 = 64;
const EMBED: u64 = 300;
const REGIONS: u64 = 8;
const HIDDEN: u64 = 128;

/// Build the regional CNN-LSTM at batch 1.
pub fn build() -> Dnn {
    let n = 1;
    let layers = vec![
        Layer::new("embed", LayerKind::Embedding, LayerShape::fc(SEQ, 128, EMBED)),
        // Regional convs (width 3 and 4 banks, 64 filters each).
        Layer::new("conv_w3", LayerKind::Conv, LayerShape { m: 64, n, c: 1, r: 3, s: EMBED, h: SEQ, w: EMBED, p: SEQ - 2, q: 1 }),
        Layer::new("conv_w4", LayerKind::Conv, LayerShape { m: 64, n, c: 1, r: 4, s: EMBED, h: SEQ, w: EMBED, p: SEQ - 3, q: 1 }),
        // Region projection then LSTM over regions.
        Layer::new("region_fc", LayerKind::Fc, LayerShape::fc(REGIONS, 128, HIDDEN)),
        Layer::new("lstm", LayerKind::Recurrent, LayerShape::recurrent(REGIONS, 1, HIDDEN, HIDDEN, 4)),
        Layer::new("fc_va", LayerKind::Fc, LayerShape::fc(n, HIDDEN, 2)),
    ];
    Dnn::chain("SA_LSTM", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        assert_eq!(build().layers.len(), 6);
    }

    #[test]
    fn lstm_gate_fusion() {
        let d = build();
        let g = d.layers[4].shape.gemm();
        assert_eq!(g.m, 4 * HIDDEN); // i, f, g, o gates
        assert_eq!(g.k, HIDDEN + HIDDEN); // input + recurrent
        assert_eq!(g.sr, REGIONS);
    }

    #[test]
    fn heavier_than_sa_cnn_convs_alone() {
        // SA_LSTM adds recurrent work on top of similar conv banks.
        let macs = build().total_macs() as f64;
        assert!((1e7..3e8).contains(&macs), "got {macs}");
    }
}
