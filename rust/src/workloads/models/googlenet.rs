//! GoogLeNet / Inception-v1 (Szegedy et al., 2015) — batch 1.
//!
//! Stem convs + 9 inception modules (each 6 conv ops: 1×1, 3×3-reduce,
//! 3×3, 5×5-reduce, 5×5, pool-proj) + fc.  ≈1.5 GMACs.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

/// (name, spatial, c_in, n1x1, n3x3red, n3x3, n5x5red, n5x5, pool_proj)
const INCEPTION: &[(&str, u64, u64, u64, u64, u64, u64, u64, u64)] = &[
    ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
    ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
    ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
    ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
    ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
    ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
    ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
    ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
    ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
];

/// Build GoogLeNet at batch 1.
pub fn build() -> Dnn {
    let n = 1;
    let mut layers = vec![
        Layer::new("conv1", LayerKind::Conv, LayerShape::conv(n, 3, 224, 224, 64, 7, 7, 2, 3)),
        Layer::new("conv2_red", LayerKind::Conv, LayerShape::conv(n, 64, 56, 56, 64, 1, 1, 1, 0)),
        Layer::new("conv2", LayerKind::Conv, LayerShape::conv(n, 64, 56, 56, 192, 3, 3, 1, 1)),
    ];
    for &(tag, sp, c_in, n1, n3r, n3, n5r, n5, pp) in INCEPTION {
        let mut conv = |name: String, c: u64, m: u64, r: u64, pad: u64| {
            layers.push(Layer::new(&name, LayerKind::Conv, LayerShape::conv(n, c, sp, sp, m, r, r, 1, pad)));
        };
        conv(format!("inc{tag}_1x1"), c_in, n1, 1, 0);
        conv(format!("inc{tag}_3x3red"), c_in, n3r, 1, 0);
        conv(format!("inc{tag}_3x3"), n3r, n3, 3, 1);
        conv(format!("inc{tag}_5x5red"), c_in, n5r, 1, 0);
        conv(format!("inc{tag}_5x5"), n5r, n5, 5, 2);
        conv(format!("inc{tag}_poolproj"), c_in, pp, 1, 0);
    }
    layers.push(Layer::new("fc", LayerKind::Fc, LayerShape::fc(n, 1024, 1000)));
    Dnn::chain("GoogleNet", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 3 stem + 9 modules * 6 + 1 fc = 58
        assert_eq!(build().layers.len(), 58);
    }

    #[test]
    fn inception_outputs_concatenate() {
        // Module output channels = n1x1 + n3x3 + n5x5 + pool_proj must
        // equal the next module's c_in within a stage.
        let out_3a = 64 + 128 + 32 + 32;
        assert_eq!(out_3a, 256);
        assert_eq!(INCEPTION[1].2, 256);
        let out_4a = 192 + 208 + 48 + 64;
        assert_eq!(out_4a, INCEPTION[3].2);
        let out_5a = 256 + 320 + 128 + 128;
        assert_eq!(out_5a, INCEPTION[8].2);
    }

    #[test]
    fn total_macs_near_published() {
        // ~1.5 GMACs at batch 1.
        let macs = build().total_macs() as f64;
        assert!((1.2e9..1.9e9).contains(&macs), "got {macs}");
    }
}
