//! Sentiment-analysis CNN (Kim-style sentence CNN with fastText
//! embeddings, after Santos et al. 2017) — batch 1.
//!
//! A 64-token sentence with 300-d embeddings, convolved by three filter
//! banks of widths 3/4/5 (100 filters each) spanning the full embedding
//! width, then a small classifier head.  Light, narrow layers — in the
//! paper's Fig. 9(c) SA_CNN completes entirely inside 128×16 partitions.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const SEQ: u64 = 64;
const EMBED: u64 = 300;
const FILTERS: u64 = 100;

/// Build the sentence-CNN at batch 1.
pub fn build() -> Dnn {
    let n = 1;
    let layers = vec![
        // Embedding lookup lowered as a skinny GEMM (vocab slice x embed).
        Layer::new("embed", LayerKind::Embedding, LayerShape::fc(SEQ, 128, EMBED)),
        // Full-width text convs: treat the sentence as a C=1 image of
        // H=SEQ, W=EMBED with R=width, S=EMBED filters (the standard
        // sentence-CNN formulation).
        Layer::new("conv_w3", LayerKind::Conv, LayerShape { m: FILTERS, n, c: 1, r: 3, s: EMBED, h: SEQ, w: EMBED, p: SEQ - 2, q: 1 }),
        Layer::new("conv_w4", LayerKind::Conv, LayerShape { m: FILTERS, n, c: 1, r: 4, s: EMBED, h: SEQ, w: EMBED, p: SEQ - 3, q: 1 }),
        Layer::new("conv_w5", LayerKind::Conv, LayerShape { m: FILTERS, n, c: 1, r: 5, s: EMBED, h: SEQ, w: EMBED, p: SEQ - 4, q: 1 }),
        // Max-pool over time then classifier.
        Layer::new("fc1", LayerKind::Fc, LayerShape::fc(n, 3 * FILTERS, 128)),
        Layer::new("fc2", LayerKind::Fc, LayerShape::fc(n, 128, 2)),
    ];
    Dnn::chain("SA_CNN", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        assert_eq!(build().layers.len(), 6);
    }

    #[test]
    fn conv_k_depth_spans_embedding() {
        let d = build();
        let g = d.layers[1].shape.gemm();
        assert_eq!(g.k, 3 * EMBED); // width-3 filter x 300-d embedding
        assert_eq!(g.m, FILTERS);
    }

    #[test]
    fn is_light_weight() {
        // Tens of MMACs, not GMACs.
        let macs = build().total_macs() as f64;
        assert!(macs < 2.5e8, "got {macs}");
    }
}
