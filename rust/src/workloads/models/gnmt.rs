//! Google Translate / GNMT (Wu et al., 2016) — batch 1, 25-token sentence.
//!
//! 8 encoder + 8 decoder LSTM layers at 1024 hidden, attention projection,
//! and a (sampled) softmax projection.  The heaviest member of the light
//! group — its final layers are the ones Fig. 9(d) shows claiming the full
//! array after the small RNNs drain out.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const SEQ: u64 = 25;
const HIDDEN: u64 = 1024;
const ENC_LAYERS: usize = 8;
const DEC_LAYERS: usize = 8;
const VOCAB_SAMPLE: u64 = 4096; // sampled-softmax projection width

/// Build GNMT at batch 1.
pub fn build() -> Dnn {
    let mut layers = vec![Layer::new(
        "embed",
        LayerKind::Embedding,
        LayerShape::fc(SEQ, 1024, HIDDEN),
    )];
    // Encoder: layer 1 is bidirectional (2x half-hidden cells ≈ one
    // full-hidden GEMM each direction).
    layers.push(Layer::new("enc0_fwd", LayerKind::Recurrent, LayerShape::recurrent(SEQ, 1, HIDDEN, HIDDEN / 2, 4)));
    layers.push(Layer::new("enc0_bwd", LayerKind::Recurrent, LayerShape::recurrent(SEQ, 1, HIDDEN, HIDDEN / 2, 4)));
    for l in 1..ENC_LAYERS {
        layers.push(Layer::new(
            &format!("enc{l}"),
            LayerKind::Recurrent,
            LayerShape::recurrent(SEQ, 1, HIDDEN, HIDDEN, 4),
        ));
    }
    // Attention projection over encoder states.
    layers.push(Layer::new("attention", LayerKind::Attention, LayerShape::fc(SEQ, HIDDEN, HIDDEN)));
    for l in 0..DEC_LAYERS {
        // Decoder layer 0 also consumes the attention context.
        let input = if l == 0 { 2 * HIDDEN } else { HIDDEN };
        layers.push(Layer::new(
            &format!("dec{l}"),
            LayerKind::Recurrent,
            LayerShape::recurrent(SEQ, 1, input, HIDDEN, 4),
        ));
    }
    layers.push(Layer::new("softmax_proj", LayerKind::Fc, LayerShape::fc(SEQ, HIDDEN, VOCAB_SAMPLE)));
    Dnn::chain("GoogleTranslate", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 1 embed + 2 bidi + 7 enc + 1 attn + 8 dec + 1 softmax = 20
        assert_eq!(build().layers.len(), 20);
    }

    #[test]
    fn decoder0_takes_context() {
        let d = build();
        let dec0 = d.layers.iter().find(|l| l.name == "dec0").unwrap();
        assert_eq!(dec0.shape.gemm().k, 2 * HIDDEN + HIDDEN);
    }

    #[test]
    fn heaviest_of_light_group() {
        // A couple of GMACs — big for the RNN group, small next to ResNet50.
        let macs = build().total_macs() as f64;
        assert!((1e9..4e9).contains(&macs), "got {macs}");
    }
}
