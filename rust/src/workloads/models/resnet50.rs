//! ResNet-50 (He et al., 2016) — ImageNet classification, batch 1.
//!
//! conv1 + 4 bottleneck stages of [3, 4, 6, 3] blocks + fc, with the v1.5
//! stride placement (stride-2 on the 3×3 of each stage's first block).
//! Downsample projection convs are included — they run on the array like
//! any other layer.  53 conv layers + 1 fc = 54 layers, ≈4.1 GMACs.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

struct StageCfg {
    blocks: usize,
    width: u64,
    /// Spatial size *after* this stage's downsampling.
    spatial: u64,
}

/// Build ResNet-50 at batch 1.
pub fn build() -> Dnn {
    let n = 1;
    let mut layers = vec![Layer::new(
        "conv1",
        LayerKind::Conv,
        LayerShape::conv(n, 3, 224, 224, 64, 7, 7, 2, 3),
    )];
    // After conv1 (112) + maxpool: 56.
    let stages = [
        StageCfg { blocks: 3, width: 64, spatial: 56 },
        StageCfg { blocks: 4, width: 128, spatial: 28 },
        StageCfg { blocks: 6, width: 256, spatial: 14 },
        StageCfg { blocks: 3, width: 512, spatial: 7 },
    ];
    let mut c_in: u64 = 64; // channels entering stage 2 (after maxpool)
    for (si, st) in stages.iter().enumerate() {
        let stage_no = si + 2; // conventional naming: conv2_x .. conv5_x
        let c_out = st.width * 4;
        for b in 0..st.blocks {
            // v1.5: stride 2 on the 3x3 of the first block of stages 3-5.
            let stride = if b == 0 && si > 0 { 2 } else { 1 };
            // Spatial entering the block: pre-downsample for the first block.
            let sp_in = if b == 0 && si > 0 { st.spatial * 2 } else { st.spatial };
            let p = |name: String, shape: LayerShape| Layer::new(&name, LayerKind::Conv, shape);
            layers.push(p(
                format!("conv{stage_no}_{b}_1x1a"),
                LayerShape::conv(n, c_in, sp_in, sp_in, st.width, 1, 1, 1, 0),
            ));
            layers.push(p(
                format!("conv{stage_no}_{b}_3x3"),
                LayerShape::conv(n, st.width, sp_in, sp_in, st.width, 3, 3, stride, 1),
            ));
            layers.push(p(
                format!("conv{stage_no}_{b}_1x1b"),
                LayerShape::conv(n, st.width, st.spatial, st.spatial, c_out, 1, 1, 1, 0),
            ));
            if b == 0 {
                // Identity-shortcut projection (stride matches the block).
                layers.push(p(
                    format!("conv{stage_no}_{b}_proj"),
                    LayerShape::conv(n, c_in, sp_in, sp_in, c_out, 1, 1, stride, 0),
                ));
            }
            c_in = c_out;
        }
    }
    layers.push(Layer::new("fc", LayerKind::Fc, LayerShape::fc(n, 2048, 1000)));
    Dnn::chain("ResNet50", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 1 (conv1) + Σ blocks·3 + 4 projections + 1 fc
        // = 1 + (3+4+6+3)*3 + 4 + 1 = 54
        assert_eq!(build().layers.len(), 54);
    }

    #[test]
    fn total_macs_near_published() {
        // ~4.1 GMACs at 224x224 batch 1.
        let macs = build().total_macs() as f64;
        assert!((3.6e9..4.6e9).contains(&macs), "got {macs}");
    }

    #[test]
    fn stage_widths_progress() {
        let d = build();
        // Final conv layer before fc outputs 2048 channels at 7x7.
        let last_conv = &d.layers[d.layers.len() - 2];
        assert_eq!(last_conv.shape.m, 2048);
        assert_eq!((last_conv.shape.p, last_conv.shape.q), (7, 7));
    }

    #[test]
    fn downsample_blocks_halve_spatial() {
        let d = build();
        // conv3_0_3x3 takes 56 -> 28
        let l = d.layers.iter().find(|l| l.name == "conv3_0_3x3").unwrap();
        assert_eq!(l.shape.h, 56);
        assert_eq!(l.shape.p, 28);
    }
}
