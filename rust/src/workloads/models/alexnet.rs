//! AlexNet (Krizhevsky et al., 2012) — ImageNet classification, batch 1.
//!
//! 5 conv + 3 FC layers (227×227 input, no-pad conv1 as in the original
//! single-GPU formulation with grouped convs merged).  The two 4096-wide FC
//! layers dominate on a weight-stationary array: `K` up to 9216 means 72
//! K-folds with a 1-row feed stream, which is why Fig. 9(c) shows AlexNet's
//! final layers occupying the full array and finishing last.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

/// Build AlexNet at batch 1.
pub fn build() -> Dnn {
    let n = 1;
    let layers = vec![
        Layer::new("conv1", LayerKind::Conv, LayerShape::conv(n, 3, 227, 227, 96, 11, 11, 4, 0)),
        Layer::new("conv2", LayerKind::Conv, LayerShape::conv(n, 96, 27, 27, 256, 5, 5, 1, 2)),
        Layer::new("conv3", LayerKind::Conv, LayerShape::conv(n, 256, 13, 13, 384, 3, 3, 1, 1)),
        Layer::new("conv4", LayerKind::Conv, LayerShape::conv(n, 384, 13, 13, 384, 3, 3, 1, 1)),
        Layer::new("conv5", LayerKind::Conv, LayerShape::conv(n, 384, 13, 13, 256, 3, 3, 1, 1)),
        Layer::new("fc6", LayerKind::Fc, LayerShape::fc(n, 256 * 6 * 6, 4096)),
        Layer::new("fc7", LayerKind::Fc, LayerShape::fc(n, 4096, 4096)),
        Layer::new("fc8", LayerKind::Fc, LayerShape::fc(n, 4096, 1000)),
    ];
    Dnn::chain("AlexNet", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count_and_kinds() {
        let d = build();
        assert_eq!(d.layers.len(), 8);
        assert_eq!(d.layers.iter().filter(|l| l.kind == LayerKind::Conv).count(), 5);
        assert_eq!(d.layers.iter().filter(|l| l.kind == LayerKind::Fc).count(), 3);
    }

    #[test]
    fn conv1_output_is_55x55() {
        let d = build();
        let s = d.layers[0].shape;
        assert_eq!((s.p, s.q), (55, 55));
    }

    #[test]
    fn total_macs_near_published() {
        // ~1.13 GMACs at batch 1 for the ungrouped (torchvision-style
        // merged-tower) formulation; the grouped original is ~0.7 G.
        let macs = build().total_macs();
        assert!((0.9e9..1.3e9).contains(&(macs as f64)), "got {macs}");
    }

    #[test]
    fn fc_layers_dominate_k_depth() {
        let d = build();
        let max_conv_k = d.layers[..5].iter().map(|l| l.shape.gemm().k).max().unwrap();
        let fc6_k = d.layers[5].shape.gemm().k;
        assert!(fc6_k > 2 * max_conv_k);
    }
}
