//! AlphaGo Zero policy/value network (Silver et al., 2017) — **reduced**
//! configuration, batch 1.
//!
//! The full AGZ tower (19–39 residual blocks × 256 filters) would be the
//! heaviest network in the pool by an order of magnitude, contradicting the
//! paper's observation that AlphaGoZero completes inside 128×16 partitions
//! among the early finishers.  We therefore use the small self-play
//! configuration (10 blocks × 64 filters on the 19×19 board) and document
//! the substitution in DESIGN.md — layer *shapes* stay faithful (3×3 convs
//! on 19×19, policy/value heads), only depth/width are the small variant.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const BOARD: u64 = 19;
const PLANES: u64 = 17;
const FILTERS: u64 = 64;
const BLOCKS: usize = 10;

/// Build the reduced AlphaGoZero network at batch 1.
pub fn build() -> Dnn {
    let n = 1;
    let mut layers = vec![Layer::new(
        "stem",
        LayerKind::Conv,
        LayerShape::conv(n, PLANES, BOARD, BOARD, FILTERS, 3, 3, 1, 1),
    )];
    for b in 0..BLOCKS {
        for half in ["a", "b"] {
            layers.push(Layer::new(
                &format!("res{b}{half}"),
                LayerKind::Conv,
                LayerShape::conv(n, FILTERS, BOARD, BOARD, FILTERS, 3, 3, 1, 1),
            ));
        }
    }
    // Policy head: 1x1 conv to 2 planes + fc to board+pass logits.
    layers.push(Layer::new("policy_conv", LayerKind::Conv, LayerShape::conv(n, FILTERS, BOARD, BOARD, 2, 1, 1, 1, 0)));
    layers.push(Layer::new("policy_fc", LayerKind::Fc, LayerShape::fc(n, 2 * BOARD * BOARD, BOARD * BOARD + 1)));
    // Value head: 1x1 conv to 1 plane + 2 fc.
    layers.push(Layer::new("value_conv", LayerKind::Conv, LayerShape::conv(n, FILTERS, BOARD, BOARD, 1, 1, 1, 1, 0)));
    layers.push(Layer::new("value_fc1", LayerKind::Fc, LayerShape::fc(n, BOARD * BOARD, 64)));
    layers.push(Layer::new("value_fc2", LayerKind::Fc, LayerShape::fc(n, 64, 1)));
    Dnn::chain("AlphaGoZero", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 1 stem + 10*2 res + 5 head layers = 26
        assert_eq!(build().layers.len(), 26);
    }

    #[test]
    fn board_spatial_preserved() {
        for l in build().layers.iter().filter(|l| l.kind == LayerKind::Conv) {
            assert_eq!((l.shape.p, l.shape.q), (BOARD, BOARD), "{}", l.name);
        }
    }

    #[test]
    fn reduced_config_stays_light() {
        // The point of the reduction: well under ResNet50.
        let macs = build().total_macs() as f64;
        assert!((1e8..1e9).contains(&macs), "got {macs}");
    }
}
