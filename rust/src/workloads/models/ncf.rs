//! Neural Collaborative Filtering (He et al. / joint NCF after Chen et al.,
//! TOIS 2019) — recommendation scoring at a serving batch of 64 candidates.
//!
//! GMF + MLP towers over user/item embeddings.  The layers are tiny
//! (M ≤ 128), which is why the paper's Fig. 9(c) shows every NCF layer
//! running inside a 128×16 partition: its GEMM columns never fill a wider
//! partition.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

/// Candidate items scored per request.
const BATCH: u64 = 64;
const EMBED: u64 = 64;

/// Build NCF scoring at a 64-candidate batch.
pub fn build() -> Dnn {
    let layers = vec![
        // Embedding lookups lowered as skinny GEMMs over the id one-hots.
        Layer::new("embed_user", LayerKind::Embedding, LayerShape::fc(BATCH, 128, EMBED)),
        Layer::new("embed_item", LayerKind::Embedding, LayerShape::fc(BATCH, 128, EMBED)),
        // MLP tower on [user ; item].
        Layer::new("mlp1", LayerKind::Fc, LayerShape::fc(BATCH, 2 * EMBED, 128)),
        Layer::new("mlp2", LayerKind::Fc, LayerShape::fc(BATCH, 128, 64)),
        Layer::new("mlp3", LayerKind::Fc, LayerShape::fc(BATCH, 64, 32)),
        // GMF element-product projection + fused prediction head.
        Layer::new("gmf_proj", LayerKind::Fc, LayerShape::fc(BATCH, EMBED, 32)),
        Layer::new("predict", LayerKind::Fc, LayerShape::fc(BATCH, 64, 1)),
    ];
    Dnn::chain("NCF", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        assert_eq!(build().layers.len(), 7);
    }

    #[test]
    fn every_layer_is_narrow() {
        // The defining property for Fig. 9(c): all output widths ≤ 128,
        // so a 16-column partition is enough once folded.
        for l in build().layers {
            assert!(l.shape.gemm().m <= 128, "{} too wide", l.name);
        }
    }

    #[test]
    fn is_tiny() {
        let macs = build().total_macs() as f64;
        assert!(macs < 5e6, "got {macs}");
    }
}
