//! Transformer-base encoder (Vaswani et al., 2017) — 6 layers, d_model 512,
//! 8 heads, FFN 2048, sequence 64, batch 1.
//!
//! Attention is lowered to the GEMMs the array actually runs: Q/K/V
//! projections, the score GEMM `QKᵀ` and context GEMM `(scores)V`
//! (aggregated across heads: per-head GEMMs share the array step and sum to
//! the same MACs), output projection, and the two FFN GEMMs.

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const SEQ: u64 = 64;
const D_MODEL: u64 = 512;
const FFN: u64 = 2048;
const LAYERS: usize = 6;

/// Build the Transformer-base encoder at batch 1.
pub fn build() -> Dnn {
    let mut layers = vec![Layer::new(
        "embed",
        LayerKind::Embedding,
        LayerShape::fc(SEQ, 512, D_MODEL),
    )];
    for l in 0..LAYERS {
        let mut push = |name: String, kind: LayerKind, sr: u64, k: u64, m: u64| {
            layers.push(Layer::new(&name, kind, LayerShape::fc(sr, k, m)));
        };
        // Fused QKV projection: [SEQ, 512] x [512, 3*512].
        push(format!("enc{l}_qkv"), LayerKind::Attention, SEQ, D_MODEL, 3 * D_MODEL);
        // Scores QK^T: per head [SEQ, 64] x [64, SEQ]; 8 heads aggregate to
        // K = d_model with M = SEQ.
        push(format!("enc{l}_scores"), LayerKind::Attention, SEQ, D_MODEL, SEQ);
        // Context (scores)V: [SEQ, SEQ] x [SEQ, 64] per head, aggregated.
        push(format!("enc{l}_context"), LayerKind::Attention, SEQ, SEQ, D_MODEL);
        // Output projection.
        push(format!("enc{l}_out"), LayerKind::Attention, SEQ, D_MODEL, D_MODEL);
        // Feed-forward.
        push(format!("enc{l}_ffn1"), LayerKind::Fc, SEQ, D_MODEL, FFN);
        push(format!("enc{l}_ffn2"), LayerKind::Fc, SEQ, FFN, D_MODEL);
    }
    Dnn::chain("Transformer", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 1 embed + 6 layers x 6 GEMMs = 37
        assert_eq!(build().layers.len(), 37);
    }

    #[test]
    fn ffn_dominates_per_layer_macs() {
        let d = build();
        let ffn1 = d.layers.iter().find(|l| l.name == "enc0_ffn1").unwrap();
        let scores = d.layers.iter().find(|l| l.name == "enc0_scores").unwrap();
        assert!(ffn1.shape.macs() > 10 * scores.shape.macs());
    }

    #[test]
    fn total_macs_near_published() {
        // 6 layers x (4·L·d² attn + 2·L·d·ffn ffn + 2·L²·d scores/context)
        // ≈ 1.25 GMACs at seq 64.
        let macs = build().total_macs() as f64;
        assert!((1.0e9..1.5e9).contains(&macs), "got {macs}");
    }
}
