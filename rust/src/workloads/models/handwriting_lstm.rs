//! Online handwriting-recognition LSTM (Carbune et al., IJDAR 2020) —
//! batch 1.
//!
//! Bézier-curve features through a 3-layer bidirectional LSTM (64 hidden
//! per direction) and a CTC character head — the smallest network in the
//! zoo, matching its 128×32-partition residency in Fig. 9(d).

use crate::workloads::dnng::{Dnn, Layer};
use crate::workloads::shapes::{LayerKind, LayerShape};

const STROKES: u64 = 512; // curve segments per written line
const FEAT: u64 = 10; // Bézier feature dim, as published
const HIDDEN: u64 = 64;
const CHARS: u64 = 100;

/// Build the handwriting LSTM at batch 1.
pub fn build() -> Dnn {
    let mut layers = Vec::new();
    let mut input = FEAT;
    for l in 0..3 {
        layers.push(Layer::new(
            &format!("blstm{l}_fwd"),
            LayerKind::Recurrent,
            LayerShape::recurrent(STROKES, 1, input, HIDDEN, 4),
        ));
        layers.push(Layer::new(
            &format!("blstm{l}_bwd"),
            LayerKind::Recurrent,
            LayerShape::recurrent(STROKES, 1, input, HIDDEN, 4),
        ));
        input = 2 * HIDDEN; // concat of both directions
    }
    layers.push(Layer::new("ctc_fc", LayerKind::Fc, LayerShape::fc(STROKES, 2 * HIDDEN, CHARS)));
    Dnn::chain("HandwritingLSTM", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        assert_eq!(build().layers.len(), 7);
    }

    #[test]
    fn smallest_in_zoo() {
        let macs = build().total_macs() as f64;
        assert!((2e7..3e8).contains(&macs), "got {macs}");
    }

    #[test]
    fn deeper_layers_take_concat_input() {
        let d = build();
        let l2 = d.layers.iter().find(|l| l.name == "blstm1_fwd").unwrap();
        assert_eq!(l2.shape.gemm().k, 2 * HIDDEN + HIDDEN);
    }
}
