//! Synthetic DNNG generator — random workload pools for stress tests,
//! property tests and the INFaaS-style serving example — plus the arrival
//! processes ([`ArrivalProcess`]) the scenario engine drives pools with.
//!
//! Generates chains of conv/FC/recurrent layers with dimension
//! distributions loosely modeled on the zoo (narrow recommendation layers
//! through wide FC projections) and Poisson arrivals.

use super::dnng::{Dnn, Layer, WorkloadPool};
use super::shapes::{LayerKind, LayerShape};
use crate::util::rng::Rng;

/// How request arrival times are generated — the serving-side dimension
/// the paper's Table-1 setup (everything at t=0) collapses; cf. the
/// arrival-driven SLO framing of "No DNN Left Behind" (arXiv 1901.06887).
///
/// All variants produce a monotone non-decreasing cycle sequence, and all
/// randomness comes from the caller's [`Rng`], so a fixed seed reproduces
/// the exact trace.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Every request arrives at cycle 0 (the paper's batch setup).
    Batch,
    /// Poisson stream: the first request at 0, then i.i.d. exponential
    /// gaps with the given mean (cycles).
    Poisson { mean_interarrival: f64 },
    /// ON-OFF bursts: `burst_size` requests spaced `within_gap` cycles
    /// apart, then an exponential OFF period with mean `between_gap`.
    Bursty { burst_size: usize, within_gap: f64, between_gap: f64 },
    /// Fixed arrival-time trace (cycles).  Sorted before use; when more
    /// requests are drawn than the trace holds, the trace tiles forward
    /// shifted by its span, keeping arrivals monotone.
    Trace(Vec<u64>),
}

/// Round a continuous cycle timestamp onto the clock grid, saturating at
/// the clock's end.  The former `t as u64` truncation biased every
/// arrival up to one cycle *early* (floor), so long traces drifted ahead
/// of the configured rate; rounding is unbiased and monotone, and the
/// saturating cast keeps absurd means (or accumulated `inf`) at
/// `u64::MAX` instead of UB-adjacent wrapping.
fn to_cycles(t: f64) -> u64 {
    debug_assert!(t >= 0.0);
    t.round() as u64 // f64 → u64 `as` saturates at the type bounds
}

impl ArrivalProcess {
    /// Validate the process parameters, naming the offending value.
    ///
    /// The config/CLI surfaces call this so a bad TOML or flag is a
    /// reported error; [`ArrivalProcess::sample`] enforces the same
    /// conditions, so programmatic misuse still fails with the same
    /// message rather than a bare assert.
    pub fn validate(&self) -> Result<(), String> {
        let finite_pos = |what: &str, v: f64| {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(format!("{what} must be a positive, finite cycle count, got {v}"))
            }
        };
        match self {
            ArrivalProcess::Batch => Ok(()),
            ArrivalProcess::Poisson { mean_interarrival } => {
                finite_pos("poisson mean_interarrival", *mean_interarrival)
            }
            ArrivalProcess::Bursty { burst_size, within_gap, between_gap } => {
                if *burst_size < 1 {
                    return Err(format!("bursty burst_size must be >= 1, got {burst_size}"));
                }
                if !within_gap.is_finite() || *within_gap < 0.0 {
                    return Err(format!(
                        "bursty within_gap must be a non-negative, finite cycle count, got {within_gap}"
                    ));
                }
                finite_pos("bursty between_gap", *between_gap)
            }
            ArrivalProcess::Trace(times) => {
                if times.is_empty() {
                    Err("arrival trace is empty — provide at least one arrival cycle".to_string())
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Sample `n` arrival cycles (monotone non-decreasing).
    ///
    /// Panics with the [`ArrivalProcess::validate`] message on invalid
    /// parameters — validate first on config-driven paths.
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<u64> {
        if let Err(e) = self.validate() {
            panic!("invalid arrival process: {e}");
        }
        match self {
            ArrivalProcess::Batch => vec![0; n],
            ArrivalProcess::Poisson { mean_interarrival } => {
                let mut t = 0.0f64;
                (0..n)
                    .map(|i| {
                        if i > 0 {
                            t += rng.gen_exp(1.0 / mean_interarrival);
                        }
                        to_cycles(t)
                    })
                    .collect()
            }
            ArrivalProcess::Bursty { burst_size, within_gap, between_gap } => {
                let mut t = 0.0f64;
                (0..n)
                    .map(|i| {
                        if i > 0 {
                            if i % burst_size == 0 {
                                t += rng.gen_exp(1.0 / between_gap); // OFF period
                            } else {
                                t += within_gap; // inside the ON burst
                            }
                        }
                        to_cycles(t)
                    })
                    .collect()
            }
            ArrivalProcess::Trace(times) => {
                let mut sorted = times.clone();
                sorted.sort_unstable();
                let period = sorted.last().unwrap() + 1;
                (0..n)
                    .map(|i| sorted[i % sorted.len()] + (i / sorted.len()) as u64 * period)
                    .collect()
            }
        }
    }
}

/// A weighted model mix — THE one cumulative-probability roll shared by
/// the INFaaS example and the fleet trace generator (both previously
/// hand-rolled the same loop).
///
/// Weights are arbitrary positive numbers; sampling normalizes by their
/// sum, so `[("a", 3.0), ("b", 1.0)]` picks `a` 75% of the time.  One
/// [`Rng::gen_f64`] draw per sample, so a mix inside a streaming
/// generator costs exactly one RNG call per request — the property the
/// fleet's determinism contract leans on.
#[derive(Debug, Clone)]
pub struct ModelMix {
    entries: Vec<(String, f64)>,
    total: f64,
}

impl ModelMix {
    /// Build a mix; panics (with the offending entry) on a non-positive
    /// or non-finite weight, or an empty mix.
    pub fn new(entries: &[(&str, f64)]) -> ModelMix {
        assert!(!entries.is_empty(), "model mix is empty");
        for (name, w) in entries {
            assert!(
                w.is_finite() && *w > 0.0,
                "model mix weight for `{name}` must be positive and finite, got {w}"
            );
        }
        let total = entries.iter().map(|(_, w)| w).sum();
        ModelMix {
            entries: entries.iter().map(|(n, w)| (n.to_string(), *w)).collect(),
            total,
        }
    }

    /// Number of models in the mix.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The `i`-th model name (mix order is definition order).
    pub fn name(&self, i: usize) -> &str {
        &self.entries[i].0
    }

    /// The `i`-th model's normalized probability.
    pub fn probability(&self, i: usize) -> f64 {
        self.entries[i].1 / self.total
    }

    /// Sample a model index: one uniform roll against the cumulative
    /// weights (first entry whose cumulative sum exceeds the roll; the
    /// last entry absorbs any floating-point residue).
    pub fn sample_index(&self, rng: &mut Rng) -> usize {
        let roll = rng.gen_f64() * self.total;
        let mut acc = 0.0;
        for (i, (_, w)) in self.entries.iter().enumerate() {
            acc += w;
            if roll < acc {
                return i;
            }
        }
        self.entries.len() - 1
    }

    /// Sample a model name (see [`ModelMix::sample_index`]).
    pub fn sample(&self, rng: &mut Rng) -> &str {
        &self.entries[self.sample_index(rng)].0
    }
}

/// Diurnal modulation over an arrival process: the instantaneous rate is
/// scaled by `1 + amplitude·sin(2π·t/period + phase)`, so a day-length
/// `period` yields the classic peak/trough serving curve (cf. the
/// production traces in "No DNN Left Behind").  Applied by
/// [`ArrivalStream`] as inverse-rate gap scaling: each sampled gap is
/// divided by the factor at the gap's *start*, which keeps generation
/// streaming (one RNG draw per arrival, no thinning rejections) and
/// monotone.
#[derive(Debug, Clone, PartialEq)]
pub struct Diurnal {
    /// Cycles per full sine period (the "day" length).
    pub period: f64,
    /// Peak-to-mean rate swing, in `[0, 1)` — 0 disables, 0.9 means the
    /// trough serves 10% of the mean rate and the peak 190%.
    pub amplitude: f64,
    /// Phase offset in radians (0 starts at the mean, rising).
    pub phase: f64,
}

impl Diurnal {
    /// Validate the modulation parameters, naming the offending value.
    pub fn validate(&self) -> Result<(), String> {
        if !self.period.is_finite() || self.period <= 0.0 {
            return Err(format!(
                "diurnal period must be a positive, finite cycle count, got {}",
                self.period
            ));
        }
        if !self.amplitude.is_finite() || !(0.0..1.0).contains(&self.amplitude) {
            return Err(format!(
                "diurnal amplitude must be in [0, 1) so the rate stays positive, got {}",
                self.amplitude
            ));
        }
        if !self.phase.is_finite() {
            return Err(format!("diurnal phase must be finite, got {}", self.phase));
        }
        Ok(())
    }

    /// Instantaneous rate multiplier at cycle `t` (always > 0 for a
    /// validated modulation).
    pub fn rate_factor(&self, t: f64) -> f64 {
        1.0 + self.amplitude * (std::f64::consts::TAU * t / self.period + self.phase).sin()
    }
}

/// A streaming arrival-time generator: the lazy, unbounded-trace twin of
/// [`ArrivalProcess::sample`], with optional [`Diurnal`] modulation.
///
/// Yields exactly the cycles `sample` would return for the same seed when
/// no modulation is attached (pinned by a property test) — but one at a
/// time, so a fleet run can stream millions of arrivals with O(1) memory
/// instead of materializing the trace up front.  Diurnal modulation
/// divides each stochastic gap by [`Diurnal::rate_factor`] at the gap's
/// start; `Batch` and `Trace` processes have no stochastic gaps and pass
/// through unmodulated.
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    process: ArrivalProcess,
    diurnal: Option<Diurnal>,
    rng: Rng,
    /// Continuous clock (pre-rounding, so rounding never accumulates).
    t: f64,
    /// Index of the next arrival to yield.
    i: usize,
    /// Total arrivals to yield.
    n: usize,
}

impl ArrivalStream {
    /// A stream of `n` arrivals; panics with the
    /// [`ArrivalProcess::validate`]/[`Diurnal::validate`] message on
    /// invalid parameters.  `Trace` processes are sorted once here.
    pub fn new(
        process: ArrivalProcess,
        diurnal: Option<Diurnal>,
        rng: Rng,
        n: usize,
    ) -> ArrivalStream {
        if let Err(e) = process.validate() {
            panic!("invalid arrival process: {e}");
        }
        if let Some(d) = &diurnal {
            if let Err(e) = d.validate() {
                panic!("invalid diurnal modulation: {e}");
            }
        }
        let process = match process {
            ArrivalProcess::Trace(mut times) => {
                times.sort_unstable();
                ArrivalProcess::Trace(times)
            }
            p => p,
        };
        ArrivalStream { process, diurnal, rng, t: 0.0, i: 0, n }
    }

    /// Arrivals still to come.
    pub fn remaining(&self) -> usize {
        self.n - self.i
    }

    /// Advance the continuous clock by a stochastic gap, shrunk (or
    /// stretched) by the diurnal rate at the gap's start.
    fn advance(&mut self, gap: f64) {
        let factor = match &self.diurnal {
            Some(d) => d.rate_factor(self.t),
            None => 1.0,
        };
        self.t += gap / factor;
    }
}

impl Iterator for ArrivalStream {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        if self.i >= self.n {
            return None;
        }
        let i = self.i;
        self.i += 1;
        // Scalar fields are copied out of the process so the stochastic
        // arms can borrow `rng`/`t` mutably; only the trace arm (which
        // draws nothing) keeps a borrow.
        let at = match self.process {
            ArrivalProcess::Batch => 0,
            ArrivalProcess::Poisson { mean_interarrival } => {
                if i > 0 {
                    let gap = self.rng.gen_exp(1.0 / mean_interarrival);
                    self.advance(gap);
                }
                to_cycles(self.t)
            }
            ArrivalProcess::Bursty { burst_size, within_gap, between_gap } => {
                if i > 0 {
                    let gap = if i % burst_size == 0 {
                        self.rng.gen_exp(1.0 / between_gap) // OFF period
                    } else {
                        within_gap // inside the ON burst
                    };
                    self.advance(gap);
                }
                to_cycles(self.t)
            }
            ArrivalProcess::Trace(ref times) => {
                let period = times.last().unwrap() + 1;
                times[i % times.len()] + (i / times.len()) as u64 * period
            }
        };
        Some(at)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.remaining();
        (rem, Some(rem))
    }
}

/// Knobs for the synthetic generator.
#[derive(Debug, Clone)]
pub struct GeneratorCfg {
    pub num_dnns: usize,
    pub layers_min: usize,
    pub layers_max: usize,
    /// Mean inter-arrival gap in cycles (exponential); 0 = all arrive at 0.
    pub mean_interarrival: f64,
    /// Scale multiplier on layer dimensions (1.0 = zoo-like).
    pub dim_scale: f64,
}

impl Default for GeneratorCfg {
    fn default() -> Self {
        GeneratorCfg {
            num_dnns: 6,
            layers_min: 3,
            layers_max: 20,
            mean_interarrival: 0.0,
            dim_scale: 1.0,
        }
    }
}

fn scaled(rng: &mut Rng, lo: u64, hi: u64, scale: f64) -> u64 {
    let v = rng.gen_range_inclusive(lo, hi) as f64 * scale;
    (v.round() as u64).max(1)
}

/// One random layer.
fn random_layer(rng: &mut Rng, idx: usize, scale: f64) -> Layer {
    let roll = rng.gen_range(100);
    if roll < 45 {
        // Conv: modest spatial, channel growth with depth.
        let c = scaled(rng, 16, 256, scale);
        let m = scaled(rng, 16, 384, scale);
        let hw = *rng.choose(&[7, 14, 28, 56]);
        let r = *rng.choose(&[1, 3, 5]);
        let pad = r / 2;
        Layer::new(
            &format!("conv{idx}"),
            LayerKind::Conv,
            LayerShape::conv(1, c, hw, hw, m, r, r, 1, pad),
        )
    } else if roll < 75 {
        // FC with a wide K tail (AlexNet-like projections).
        let k = scaled(rng, 64, 4096, scale);
        let m = scaled(rng, 16, 2048, scale);
        let batch = *rng.choose(&[1, 1, 1, 4, 16]);
        Layer::new(&format!("fc{idx}"), LayerKind::Fc, LayerShape::fc(batch, k, m))
    } else {
        // Recurrent step.
        let hidden = *rng.choose(&[64, 128, 256, 512, 1024]);
        let hidden = ((hidden as f64 * scale).round() as u64).max(8);
        let seq = rng.gen_range_inclusive(10, 120);
        let gates = *rng.choose(&[3, 4]);
        Layer::new(
            &format!("rnn{idx}"),
            LayerKind::Recurrent,
            LayerShape::recurrent(seq, 1, hidden, hidden, gates),
        )
    }
}

/// Generate one random chain DNN.
pub fn random_dnn(rng: &mut Rng, name: &str, cfg: &GeneratorCfg) -> Dnn {
    let n_layers = rng.gen_range_inclusive(cfg.layers_min as u64, cfg.layers_max as u64) as usize;
    let layers = (0..n_layers).map(|i| random_layer(rng, i, cfg.dim_scale)).collect();
    Dnn::chain(name, layers)
}

/// Generate a pool with Poisson arrivals.
pub fn random_pool(rng: &mut Rng, cfg: &GeneratorCfg) -> WorkloadPool {
    let mut dnns = Vec::with_capacity(cfg.num_dnns);
    let mut t = 0.0f64;
    for i in 0..cfg.num_dnns {
        let mut d = random_dnn(rng, &format!("synthetic{i}"), cfg);
        if cfg.mean_interarrival > 0.0 && i > 0 {
            t += rng.gen_exp(1.0 / cfg.mean_interarrival);
        }
        d.arrival_cycles = to_cycles(t);
        dnns.push(d);
    }
    WorkloadPool::new("synthetic", dnns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn generated_pools_validate() {
        prop::check("generated pools are well-formed", 50, |rng| {
            let cfg = GeneratorCfg {
                num_dnns: rng.gen_range_inclusive(1, 10) as usize,
                layers_min: 1,
                layers_max: 12,
                mean_interarrival: if rng.gen_bool(0.5) { 1000.0 } else { 0.0 },
                dim_scale: 0.25 + rng.gen_f64(),
            };
            let pool = random_pool(rng, &cfg);
            prop::ensure_eq(pool.dnns.len(), cfg.num_dnns, "dnn count")?;
            for d in &pool.dnns {
                d.validate();
                prop::ensure(
                    d.layers.len() >= cfg.layers_min && d.layers.len() <= cfg.layers_max,
                    "layer count in range",
                )?;
                for l in &d.layers {
                    let g = l.shape.gemm();
                    prop::ensure(g.sr > 0 && g.k > 0 && g.m > 0, "positive GEMM dims")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn arrivals_are_monotone() {
        let mut rng = Rng::new(9);
        let cfg = GeneratorCfg { num_dnns: 20, mean_interarrival: 500.0, ..Default::default() };
        let pool = random_pool(&mut rng, &cfg);
        for w in pool.dnns.windows(2) {
            assert!(w[0].arrival_cycles <= w[1].arrival_cycles);
        }
        assert!(pool.dnns.last().unwrap().arrival_cycles > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = GeneratorCfg::default();
        let a = random_pool(&mut Rng::new(7), &cfg);
        let b = random_pool(&mut Rng::new(7), &cfg);
        assert_eq!(a.total_macs(), b.total_macs());
        assert_eq!(a.total_layers(), b.total_layers());
    }

    fn is_monotone(xs: &[u64]) -> bool {
        xs.windows(2).all(|w| w[0] <= w[1])
    }

    #[test]
    fn arrival_batch_is_all_zero() {
        let mut rng = Rng::new(1);
        assert_eq!(ArrivalProcess::Batch.sample(&mut rng, 5), vec![0; 5]);
    }

    #[test]
    fn arrival_poisson_monotone_and_deterministic() {
        let p = ArrivalProcess::Poisson { mean_interarrival: 10_000.0 };
        let a = p.sample(&mut Rng::new(3), 50);
        let b = p.sample(&mut Rng::new(3), 50);
        assert_eq!(a, b);
        assert!(is_monotone(&a));
        assert_eq!(a[0], 0);
        assert!(*a.last().unwrap() > 0, "50 draws at mean 10k cannot all collapse to 0");
    }

    #[test]
    fn arrival_bursty_shape() {
        let p = ArrivalProcess::Bursty { burst_size: 4, within_gap: 100.0, between_gap: 50_000.0 };
        let a = p.sample(&mut Rng::new(9), 16);
        assert!(is_monotone(&a));
        // Inside a burst the spacing is exactly within_gap.
        for (i, w) in a.windows(2).enumerate() {
            if (i + 1) % 4 != 0 {
                assert_eq!(w[1] - w[0], 100, "intra-burst gap at {i}: {a:?}");
            }
        }
    }

    #[test]
    fn validate_names_the_offending_value() {
        let e = ArrivalProcess::Poisson { mean_interarrival: 0.0 }.validate().unwrap_err();
        assert!(e.contains("mean_interarrival") && e.contains('0'), "{e}");
        let e = ArrivalProcess::Poisson { mean_interarrival: f64::NAN }.validate().unwrap_err();
        assert!(e.contains("NaN"), "{e}");
        let e = ArrivalProcess::Bursty { burst_size: 0, within_gap: 1.0, between_gap: 1.0 }
            .validate()
            .unwrap_err();
        assert!(e.contains("burst_size"), "{e}");
        let e = ArrivalProcess::Bursty { burst_size: 2, within_gap: -3.0, between_gap: 1.0 }
            .validate()
            .unwrap_err();
        assert!(e.contains("-3"), "{e}");
        let e = ArrivalProcess::Trace(vec![]).validate().unwrap_err();
        assert!(e.contains("empty"), "{e}");
        assert!(ArrivalProcess::Batch.validate().is_ok());
        assert!(ArrivalProcess::Trace(vec![5]).validate().is_ok());
        assert!(ArrivalProcess::Poisson { mean_interarrival: 10.0 }.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn sampling_an_invalid_process_panics_with_the_validate_message() {
        ArrivalProcess::Trace(vec![]).sample(&mut Rng::new(1), 3);
    }

    #[test]
    fn arrival_gaps_nonnegative_and_mean_matches_config() {
        // Round-then-saturate must keep arrivals monotone and unbiased:
        // the measured mean inter-arrival gap of a long trace matches the
        // configured mean within CLT noise (truncation's systematic
        // half-cycle-early bias is gone; f64 accumulation is exact at
        // these magnitudes).
        prop::check("arrival mean matches config", 15, |rng| {
            let mean = 500.0 + rng.gen_f64() * 50_000.0;
            let n = 4000usize;
            let a = ArrivalProcess::Poisson { mean_interarrival: mean }.sample(rng, n);
            for w in a.windows(2) {
                prop::ensure(w[0] <= w[1], "gaps never negative")?;
            }
            let measured = *a.last().unwrap() as f64 / (n - 1) as f64;
            // sd/mean of the sample mean is 1/sqrt(n-1) ≈ 1.6%; 10% is
            // a > 6-sigma envelope.
            prop::ensure(
                (measured - mean).abs() < 0.10 * mean,
                &format!("measured mean {measured:.1} vs configured {mean:.1}"),
            )
        });
    }

    #[test]
    fn absurd_means_saturate_instead_of_wrapping() {
        let p = ArrivalProcess::Poisson { mean_interarrival: 1e300 };
        let a = p.sample(&mut Rng::new(4), 8);
        assert_eq!(a[0], 0);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "saturation keeps monotonicity: {a:?}");
        assert!(a[1..].iter().all(|&t| t >= 1u64 << 63), "huge means land near the clock end");
    }

    #[test]
    fn arrival_trace_sorts_and_tiles() {
        let p = ArrivalProcess::Trace(vec![500, 0, 100]);
        let mut rng = Rng::new(0);
        // First pass sorted, second pass shifted by last+1 = 501.
        assert_eq!(p.sample(&mut rng, 6), vec![0, 100, 500, 501, 601, 1001]);
        assert_eq!(p.sample(&mut rng, 2), vec![0, 100]);
    }

    #[test]
    fn stream_matches_batch_sample_exactly() {
        // The streaming generator is the lazy twin of `sample`: same
        // seed, same process, same RNG call order ⇒ the same cycles,
        // element for element — over every process variant.
        prop::check("stream == sample", 40, |rng| {
            let seed = rng.next_u64();
            let n = rng.gen_range_inclusive(1, 200) as usize;
            let p = match rng.gen_range(4) {
                0 => ArrivalProcess::Batch,
                1 => ArrivalProcess::Poisson {
                    mean_interarrival: 100.0 + rng.gen_f64() * 50_000.0,
                },
                2 => ArrivalProcess::Bursty {
                    burst_size: rng.gen_range_inclusive(1, 8) as usize,
                    within_gap: rng.gen_f64() * 500.0,
                    between_gap: 100.0 + rng.gen_f64() * 50_000.0,
                },
                _ => ArrivalProcess::Trace(
                    (0..rng.gen_range_inclusive(1, 10)).map(|_| rng.gen_range(10_000)).collect(),
                ),
            };
            let eager = p.sample(&mut Rng::new(seed), n);
            let lazy: Vec<u64> =
                ArrivalStream::new(p, None, Rng::new(seed), n).collect();
            prop::ensure_eq(lazy, eager, "streamed arrivals")
        });
    }

    #[test]
    fn stream_is_monotone_under_diurnal() {
        prop::check("diurnal stream monotone", 20, |rng| {
            let d = Diurnal {
                period: 1e5 + rng.gen_f64() * 1e7,
                amplitude: rng.gen_f64() * 0.99,
                phase: rng.gen_f64() * std::f64::consts::TAU,
            };
            let p = ArrivalProcess::Poisson { mean_interarrival: 5_000.0 };
            let a: Vec<u64> =
                ArrivalStream::new(p, Some(d), Rng::new(rng.next_u64()), 500).collect();
            prop::ensure_eq(a.len(), 500, "stream length")?;
            for w in a.windows(2) {
                prop::ensure(w[0] <= w[1], "monotone under modulation")?;
            }
            Ok(())
        });
    }

    #[test]
    fn diurnal_peak_runs_faster_than_trough() {
        // Phase π/2 starts the stream at the rate peak (factor 1+a);
        // phase 3π/2 at the trough (factor 1-a).  Early in the stream
        // (well inside the first quarter-period) the peak-phase clock
        // must advance slower per arrival — i.e. arrivals are denser.
        let p = ArrivalProcess::Poisson { mean_interarrival: 1_000.0 };
        let period = 1e9; // so 200 arrivals stay near t≈0 phase
        let mk = |phase: f64| {
            let d = Diurnal { period, amplitude: 0.8, phase };
            ArrivalStream::new(p.clone(), Some(d), Rng::new(11), 200)
                .last()
                .unwrap()
        };
        let peak_end = mk(std::f64::consts::FRAC_PI_2);
        let trough_end = mk(1.5 * std::f64::consts::PI);
        // Identical seeds ⇒ identical gap draws; only the factor differs:
        // (1-a)/(1+a) = 0.111..., so the spread is wide and stable.
        assert!(
            (peak_end as f64) < 0.2 * trough_end as f64,
            "peak {peak_end} !<< trough {trough_end}"
        );
    }

    #[test]
    fn diurnal_validate_names_the_offending_value() {
        let ok = Diurnal { period: 1e6, amplitude: 0.5, phase: 0.0 };
        assert!(ok.validate().is_ok());
        let e = Diurnal { period: 0.0, ..ok.clone() }.validate().unwrap_err();
        assert!(e.contains("period"), "{e}");
        let e = Diurnal { amplitude: 1.0, ..ok.clone() }.validate().unwrap_err();
        assert!(e.contains("amplitude") && e.contains('1'), "{e}");
        let e = Diurnal { amplitude: -0.1, ..ok.clone() }.validate().unwrap_err();
        assert!(e.contains("-0.1"), "{e}");
        let e = Diurnal { phase: f64::INFINITY, ..ok }.validate().unwrap_err();
        assert!(e.contains("phase"), "{e}");
    }

    #[test]
    fn model_mix_frequencies_match_weights() {
        // Chi-square goodness of fit: X² = Σ (obs-exp)²/exp over the
        // categories is ~χ²(k-1) under the null; 40 is far beyond the
        // 99.9th percentile for k ≤ 6, so a correct sampler essentially
        // never trips while a biased one (e.g. unnormalized weights)
        // blows through it immediately.
        prop::check("mix chi-square", 10, |rng| {
            let k = rng.gen_range_inclusive(2, 6) as usize;
            let entries: Vec<(String, f64)> =
                (0..k).map(|i| (format!("m{i}"), 0.25 + rng.gen_f64() * 4.0)).collect();
            let refs: Vec<(&str, f64)> =
                entries.iter().map(|(n, w)| (n.as_str(), *w)).collect();
            let mix = ModelMix::new(&refs);
            let n = 8_000usize;
            let mut counts = vec![0usize; k];
            for _ in 0..n {
                counts[mix.sample_index(rng)] += 1;
            }
            let chi2: f64 = (0..k)
                .map(|i| {
                    let exp = mix.probability(i) * n as f64;
                    let d = counts[i] as f64 - exp;
                    d * d / exp
                })
                .sum();
            prop::ensure(chi2 < 40.0, &format!("chi-square {chi2:.1} (counts {counts:?})"))
        });
    }

    #[test]
    fn model_mix_sample_returns_names() {
        let mix = ModelMix::new(&[("a", 1.0), ("b", 3.0)]);
        assert_eq!(mix.len(), 2);
        assert_eq!(mix.name(1), "b");
        assert!((mix.probability(1) - 0.75).abs() < 1e-12);
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let s = mix.sample(&mut rng);
            assert!(s == "a" || s == "b");
        }
    }

    #[test]
    #[should_panic(expected = "weight for `bad`")]
    fn model_mix_rejects_bad_weight() {
        ModelMix::new(&[("ok", 1.0), ("bad", 0.0)]);
    }
}
