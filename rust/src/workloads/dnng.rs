//! DNN graphs (DNNG) and the multi-DNN workload pool (paper §2.1, Fig. 2).
//!
//! A DNNG is a weighted DAG of layers; in the paper's evaluation (and the
//! published networks it uses) every DNNG is a chain — layer `i+1` depends
//! on layer `i` — so the graph is stored as an ordered layer list plus an
//! explicit dependency edge list to keep the general DAG form available to
//! the scheduler (it only dispatches layers whose predecessors completed).

use super::shapes::{op_class, LayerKind, LayerShape, OpClass};

/// Identifies a DNN within a pool.
pub type DnnId = usize;

/// Identifies a layer within its DNN.
pub type LayerId = usize;

/// One DNN layer (a DNNG vertex).
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub shape: LayerShape,
}

impl Layer {
    pub fn new(name: &str, kind: LayerKind, shape: LayerShape) -> Layer {
        Layer { name: name.to_string(), kind, shape }
    }

    /// Resource-class of this layer (op kind × arithmetic intensity) —
    /// what an intensity-aware policy reads to route the layer to the
    /// systolic array or the vector lanes.  Derivable entirely from the
    /// existing dims; no workload file carries any new field.
    pub fn op_class(&self) -> OpClass {
        op_class(self.kind, self.shape.gemm())
    }
}

/// One deep neural network graph.
#[derive(Debug, Clone)]
pub struct Dnn {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Dependency edges `(from, to)`; empty means pure chain.
    pub edges: Vec<(LayerId, LayerId)>,
    /// Arrival time `A_t` in cycles (assigned by the pool / generator).
    pub arrival_cycles: u64,
}

impl Dnn {
    /// Chain-topology DNN (the common case).
    pub fn chain(name: &str, layers: Vec<Layer>) -> Dnn {
        let edges = (1..layers.len()).map(|i| (i - 1, i)).collect();
        Dnn { name: name.to_string(), layers, edges, arrival_cycles: 0 }
    }

    /// Set the arrival time (builder style).
    pub fn arriving_at(mut self, cycles: u64) -> Dnn {
        self.arrival_cycles = cycles;
        self
    }

    /// Direct predecessors of `layer`.
    pub fn preds(&self, layer: LayerId) -> impl Iterator<Item = LayerId> + '_ {
        self.edges.iter().filter(move |(_, t)| *t == layer).map(|(f, _)| *f)
    }

    /// Total `Opr` (Eq. 2) over all layers.
    pub fn total_opr(&self) -> u64 {
        self.layers.iter().map(|l| l.shape.opr()).sum()
    }

    /// Total true MACs over all layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.shape.macs()).sum()
    }

    /// True when the majority of this DNN's layers are memory-bound —
    /// the tenant-granularity view of [`Layer::op_class`] that fleet
    /// placement and reports use (a GNMT/LSTM tenant reads memory-bound;
    /// a ResNet tenant reads compute-bound).
    pub fn memory_bound(&self) -> bool {
        let mb = self.layers.iter().filter(|l| l.op_class() == OpClass::MemoryBound).count();
        2 * mb > self.layers.len()
    }

    /// Validate DAG-ness and edge bounds (panics on malformed graphs;
    /// called by the pool constructor).
    pub fn validate(&self) {
        assert!(!self.layers.is_empty(), "DNN {} has no layers", self.name);
        for &(f, t) in &self.edges {
            assert!(f < self.layers.len() && t < self.layers.len(), "edge out of range in {}", self.name);
            assert!(f < t, "edge {f}->{t} violates topological layer order in {}", self.name);
        }
    }
}

/// A pool of DNNs submitted to the accelerator (the task queue's source).
#[derive(Debug, Clone)]
pub struct WorkloadPool {
    pub name: String,
    pub dnns: Vec<Dnn>,
}

impl WorkloadPool {
    pub fn new(name: &str, dnns: Vec<Dnn>) -> WorkloadPool {
        for d in &dnns {
            d.validate();
        }
        WorkloadPool { name: name.to_string(), dnns }
    }

    pub fn total_layers(&self) -> usize {
        self.dnns.iter().map(|d| d.layers.len()).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.dnns.iter().map(|d| d.total_macs()).sum()
    }

    /// DNNs sorted by arrival time (stable: ties keep pool order).
    pub fn by_arrival(&self) -> Vec<DnnId> {
        let mut ids: Vec<DnnId> = (0..self.dnns.len()).collect();
        ids.sort_by_key(|&i| self.dnns[i].arrival_cycles);
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dnn(name: &str, n_layers: usize) -> Dnn {
        let layers = (0..n_layers)
            .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(1, 64, 64)))
            .collect();
        Dnn::chain(name, layers)
    }

    #[test]
    fn chain_edges() {
        let d = small_dnn("a", 4);
        assert_eq!(d.edges, vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(d.preds(2).collect::<Vec<_>>(), vec![1]);
        assert_eq!(d.preds(0).count(), 0);
    }

    #[test]
    fn totals() {
        let d = small_dnn("a", 3);
        assert_eq!(d.total_opr(), 3 * 64 * 64);
        assert_eq!(d.total_macs(), 3 * 64 * 64);
    }

    #[test]
    fn tenant_memory_bound_majority() {
        // Tiny FC layers at batch 1 are memory-bound; a chain of them
        // reads as a memory-bound tenant.
        let d = small_dnn("lstm-ish", 3);
        assert_eq!(d.layers[0].op_class(), crate::workloads::shapes::OpClass::MemoryBound);
        assert!(d.memory_bound());
        // A conv chain is compute-bound by kind.
        let conv = Dnn::chain(
            "resnet-ish",
            (0..3)
                .map(|i| {
                    Layer::new(
                        &format!("c{i}"),
                        LayerKind::Conv,
                        LayerShape::conv(1, 64, 56, 56, 64, 3, 3, 1, 1),
                    )
                })
                .collect(),
        );
        assert!(!conv.memory_bound());
    }

    #[test]
    #[should_panic(expected = "violates topological")]
    fn rejects_back_edge() {
        let mut d = small_dnn("a", 2);
        d.edges.push((1, 0));
        d.validate();
    }

    #[test]
    fn pool_ordering_by_arrival() {
        let pool = WorkloadPool::new(
            "p",
            vec![
                small_dnn("late", 1).arriving_at(100),
                small_dnn("early", 1).arriving_at(5),
                small_dnn("tie-first", 1).arriving_at(5),
            ],
        );
        // stable sort keeps "early" (index 1) before "tie-first" (index 2)
        assert_eq!(pool.by_arrival(), vec![1, 2, 0]);
        assert_eq!(pool.total_layers(), 3);
    }
}
