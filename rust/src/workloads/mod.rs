//! Workload model — the paper's §2.1 Deep Neural Network Graph (DNNG).
//!
//! - [`shapes`] — the 9-dimension layer shape tuple (Eq. 1), MAC-operation
//!   count `Opr(l)` (Eq. 2), and the conv→GEMM lowering the systolic array
//!   actually executes.
//! - [`dnng`] — layers, DNN graphs, arrival times, and the multi-DNN pool.
//! - [`models`] — the 12-network zoo of Table 1 (heavy multi-domain group +
//!   light RNN group), transcribed from the published architectures.
//! - [`generator`] — synthetic DNNG generator (random graphs, Poisson
//!   arrivals) for stress and property tests.

pub mod dnng;
pub mod generator;
pub mod models;
pub mod shapes;

pub use dnng::{Dnn, DnnId, Layer, LayerId, WorkloadPool};
pub use shapes::{GemmDims, LayerKind, LayerShape};
