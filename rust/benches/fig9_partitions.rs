//! Fig. 9(c)(d) — per-DNN partition-size detail: which partition widths
//! each DNN's layers executed on, with start/end cycles — the dispatch
//! log behind the paper's stacked detail plots.  The expected shape: small
//! DNNs live in 128×16/128×32 partitions; stragglers' final layers claim
//! merged (up to full-width) partitions.

use mtsa::benchkit::section;
use mtsa::coordinator::scheduler::{AllocPolicy, SchedulerConfig};
use mtsa::report;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::models::{heavy_pool, light_pool};

fn fig(pool: &mtsa::workloads::dnng::WorkloadPool, tag: &str, policy: AllocPolicy, pname: &str) {
    let cfg = SchedulerConfig::default();
    let g = report::run_group_with_policy(pool, &cfg, policy);
    section(&format!("Fig 9({tag}) partition detail — {} — policy {pname}", pool.name));

    // Per-DNN summary: widths used and the width of the final layer.
    let mut t = Table::new(&["DNN", "layers", "widths used", "final-layer width", "done@"]);
    for (name, done) in &g.dynamic.completion {
        let trace = g.dynamic.partition_trace(name);
        t.row(&[
            name.clone(),
            trace.len().to_string(),
            format!("{:?}", g.dynamic.partition_widths(name)),
            trace.last().unwrap().to_string(),
            done.to_string(),
        ]);
    }
    println!("{}", t.render());

    // Width histogram over dispatches (the ladder).
    let mut hist = std::collections::BTreeMap::new();
    for d in &g.dynamic.dispatches {
        *hist.entry(d.tile.cols).or_insert(0u64) += 1;
    }
    let mut t = Table::new(&["partition width", "layer dispatches"]);
    for (w, n) in hist {
        t.row(&[format!("128x{w}"), n.to_string()]);
    }
    println!("{}", t.render());
}

fn main() {
    for (pool, tag) in [(heavy_pool(), "c"), (light_pool(), "d")] {
        fig(&pool, tag, AllocPolicy::EqualShare, "equal(paper-literal)");
        fig(&pool, tag, AllocPolicy::WidestToHeaviest, "widest(demand-aware)");
    }
}
