//! P1 — hot-path micro-benchmarks (the §Perf targets of EXPERIMENTS.md):
//!
//! - analytic layer timing (the scheduler's inner-loop cost model),
//! - a full dynamic-scheduler run over the heavy pool,
//! - partition manager alloc/free churn,
//! - PJRT artifact execution latency + packing (skipped if artifacts are
//!   not built).

use mtsa::benchkit::Bench;
use mtsa::coordinator::scheduler::{DynamicScheduler, SchedulerConfig};
use mtsa::coordinator::PartitionManager;
use mtsa::runtime::{pack_step, Tensor, TenantTile};
use mtsa::sim::buffers::BufferConfig;
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::sim::partitioned::{slice_layer_timing, FeedPolicy, PartitionSlice};
use mtsa::util::rng::Rng;
use mtsa::workloads::models::heavy_pool;
use mtsa::workloads::shapes::GemmDims;

fn main() {
    let mut b = Bench::new("hotpath");

    // Analytic timing model: the per-dispatch cost inside the scheduler.
    let geom = ArrayGeometry::new(128, 128);
    let bufs = BufferConfig::default();
    let gemm = GemmDims { sr: 3025, k: 1152, m: 384 };
    b.measure("slice_layer_timing (conv layer)", || {
        std::hint::black_box(slice_layer_timing(
            geom,
            std::hint::black_box(gemm),
            PartitionSlice::new(32, 32),
            FeedPolicy::Independent,
            &bufs,
        ));
    });

    // Whole-pool scheduler run (the end-to-end simulation cost).
    let pool = heavy_pool();
    let sched = DynamicScheduler::new(SchedulerConfig::default());
    b.measure("DynamicScheduler::run (heavy pool, 202 layers)", || {
        std::hint::black_box(sched.run(&pool));
    });

    // Partition manager churn.
    b.measure("PartitionManager alloc/free x64", || {
        let mut pm = PartitionManager::new(geom);
        let mut rng = Rng::new(1);
        let mut live = Vec::new();
        for _ in 0..64 {
            if live.is_empty() || rng.gen_bool(0.6) {
                if let Some((id, _)) = pm.allocate(rng.gen_range_inclusive(8, 64)) {
                    live.push(id);
                }
            } else {
                let i = rng.gen_range(live.len() as u64) as usize;
                pm.free(live.swap_remove(i));
            }
        }
        for id in live {
            pm.free(id);
        }
    });

    // Tenant packing (pure rust; no artifacts needed).
    let mut rng = Rng::new(2);
    let rand = |rng: &mut Rng, shape: Vec<usize>| {
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gen_f32() - 0.5).collect())
    };
    let tiles: Vec<TenantTile> = (0..4)
        .map(|t| TenantTile {
            tenant: t,
            x: rand(&mut rng, vec![128, 128]),
            w: rand(&mut rng, vec![128, 32]),
        })
        .collect();
    b.measure("pack_step (4 tenants, 128x128)", || {
        std::hint::black_box(pack_step(&tiles, 128, 128, 128, 4).unwrap());
    });

    pjrt_engine_benches(&tiles);

    b.finish();
}

/// PJRT execution latency (requires the `pjrt` feature + built artifacts).
#[cfg(feature = "pjrt")]
fn pjrt_engine_benches(tiles: &[TenantTile]) {
    use mtsa::benchkit::BenchOpts;
    use mtsa::runtime::Engine;

    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(artifacts not built; skipping PJRT benches)");
        return;
    }
    let engine = Engine::load(&dir).expect("engine");
    let step = pack_step(tiles, 128, 128, 128, 4).unwrap();
    let acc = Tensor::zeros(vec![128, 128]);
    let opts = BenchOpts { min_iters: 20, ..Default::default() };
    let mut b2 = Bench::new("pjrt").with_opts(opts);
    b2.measure("engine.execute pws_p4 (one array step)", || {
        std::hint::black_box(
            engine
                .execute(
                    "pws_p4",
                    &[step.x.clone(), step.w.clone(), step.mask.clone(), acc.clone()],
                )
                .unwrap(),
        );
    });
    let x0 = tiles[0].x.clone();
    b2.measure("engine.execute gemm_baseline", || {
        std::hint::black_box(
            engine
                .execute("gemm_baseline", &[x0.clone(), step.w.clone(), acc.clone()])
                .unwrap(),
        );
    });
    b2.finish();
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_engine_benches(_tiles: &[TenantTile]) {
    eprintln!("(built without the `pjrt` feature; skipping PJRT benches)");
}
