//! Fig. 9(e)(f) — per-DNN energy, baseline vs dynamic partitioning.
//!
//! Two accountings are printed (see DESIGN.md §5 / EXPERIMENTS.md):
//!
//! - **per-DNN bars** — the paper's figure structure: each DNN's dynamic
//!   energy plus array static energy attributed to its residency windows
//!   (full array when exclusive, width-fraction when partitioned);
//! - **run totals** — dynamic + makespan-static, with the component
//!   breakdown (MAC / SRAM / DRAM / static).

use mtsa::benchkit::section;
use mtsa::coordinator::scheduler::{AllocPolicy, SchedulerConfig};
use mtsa::energy::EnergyModel;
use mtsa::report;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::models::{heavy_pool, light_pool};

fn fig(pool: &mtsa::workloads::dnng::WorkloadPool, tag: &str, policy: AllocPolicy, pname: &str) {
    let cfg = SchedulerConfig::default();
    let model = EnergyModel::default_128();
    let g = report::run_group_with_policy(pool, &cfg, policy);

    section(&format!("Fig 9({tag}) energy — {} — policy {pname}", pool.name));
    let bars_seq = report::per_dnn_energy_bars(&g.sequential, &model);
    let bars_dyn = report::per_dnn_energy_bars(&g.dynamic, &model);
    let mut t = Table::new(&["DNN", "baseline (mJ)", "partitioned (mJ)", "saving"]);
    for (name, seq_j) in &bars_seq {
        let dyn_j = bars_dyn[name];
        t.row(&[
            name.clone(),
            format!("{:.3}", seq_j * 1e3),
            format!("{:.3}", dyn_j * 1e3),
            format!("{:+.1}%", report::saving_pct(*seq_j, dyn_j)),
        ]);
    }
    let (ssum, dsum) = (bars_seq.values().sum::<f64>(), bars_dyn.values().sum::<f64>());
    t.row(&[
        "== sum of bars ==".into(),
        format!("{:.3}", ssum * 1e3),
        format!("{:.3}", dsum * 1e3),
        format!("{:+.1}%", report::saving_pct(ssum, dsum)),
    ]);
    println!("{}", t.render());

    let es = report::total_energy(&g.sequential, &model);
    let ed = report::total_energy(&g.dynamic, &model);
    let mut t = Table::new(&["component", "baseline (mJ)", "partitioned (mJ)"]);
    for (name, seq_j) in &es.dynamic_by_component {
        t.row(&[
            name.to_string(),
            format!("{:.3}", seq_j * 1e3),
            format!("{:.3}", ed.dynamic_by_component[name] * 1e3),
        ]);
    }
    t.row(&[
        "static (makespan)".into(),
        format!("{:.3}", es.static_j * 1e3),
        format!("{:.3}", ed.static_j * 1e3),
    ]);
    t.row(&[
        "== total ==".into(),
        format!("{:.3}", es.total_j() * 1e3),
        format!("{:.3}", ed.total_j() * 1e3),
    ]);
    println!("{}", t.render());
    println!(
        "total-energy saving: {:+.1}%   (paper H1: 35% heavy / 62% light)",
        report::saving_pct(es.total_j(), ed.total_j())
    );
}

fn main() {
    for (pool, tag) in [(heavy_pool(), "e"), (light_pool(), "f")] {
        fig(&pool, tag, AllocPolicy::EqualShare, "equal(paper-literal)");
        fig(&pool, tag, AllocPolicy::WidestToHeaviest, "widest(demand-aware)");
    }
}
