//! Sweep throughput — how fast the parallel scenario runner chews through
//! grid points, and how it scales with worker threads.
//!
//! Each point is a full dynamic + sequential simulation of an
//! arrival-driven scenario, so this doubles as a macro-benchmark of the
//! scheduler hot path under serving-style workloads.  Output in
//! points/sec makes runs comparable as the grid grows.

use std::time::Duration;

use mtsa::benchkit::{section, Bench, BenchOpts};
use mtsa::coordinator::scheduler::{AllocPolicy, FeedModel, SchedulerConfig};
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::sweep::{run_sweep, SweepGrid};

fn bench_grid() -> SweepGrid {
    SweepGrid {
        mixes: vec!["light".to_string()],
        rates: vec![0.0, 30_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare],
        feeds: vec![FeedModel::Independent, FeedModel::Interleaved],
        geoms: vec![ArrayGeometry::new(128, 128)],
        requests: 6,
        qos_slack: 3.0,
        bursty: None,
        seed: 11,
        ..SweepGrid::default()
    }
}

fn main() {
    section("sweep throughput (8-point light-mix grid, 6 requests/point)");
    let base = SchedulerConfig::default();
    let grid = bench_grid();
    let points = 8.0;

    let opts = BenchOpts {
        warmup: Duration::from_millis(200),
        measure: Duration::from_secs(2),
        min_iters: 3,
        max_iters: 200,
    };
    let mut b = Bench::new("sweep").with_opts(opts);
    for threads in [1usize, 2, 4, 8] {
        let s = b.measure(&format!("run_sweep x8 points, {threads} thread(s)"), || {
            let rows = run_sweep(&grid, &base, threads).expect("sweep");
            std::hint::black_box(rows);
        });
        println!(
            "  -> {:.1} points/sec at {threads} thread(s)",
            points / (s.mean / 1e9)
        );
    }
    b.finish();
}
