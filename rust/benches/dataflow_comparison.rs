//! Dataflow comparison (paper §2 preliminaries): weight stationary vs
//! input stationary vs output stationary, per zoo network — why the
//! paper's substrate (and the TPU) is WS, and where the alternatives win.

use mtsa::benchkit::section;
use mtsa::sim::alt_dataflows::{input_stationary_timing, output_stationary_timing};
use mtsa::sim::buffers::BufferConfig;
use mtsa::sim::dataflow::{baseline_layer_timing, ArrayGeometry};
use mtsa::util::tablefmt::Table;
use mtsa::workloads::models::ZOO;

fn main() {
    let geom = ArrayGeometry::new(128, 128);
    let bufs = BufferConfig::default();

    section("Dataflow comparison: total cycles per network (single tenant, full array)");
    let mut t = Table::new(&["model", "WS (k-cycles)", "IS (k-cycles)", "OS (k-cycles)", "best"]);
    let mut ws_wins = 0usize;
    for e in ZOO {
        let dnn = (e.build)();
        let mut ws = 0u64;
        let mut is = 0u64;
        let mut os = 0u64;
        for l in &dnn.layers {
            let g = l.shape.gemm();
            ws += baseline_layer_timing(geom, g, &bufs).cycles;
            is += input_stationary_timing(geom, g, &bufs).cycles;
            os += output_stationary_timing(geom, g, &bufs).cycles;
        }
        let best = if ws <= is && ws <= os {
            ws_wins += 1;
            "WS"
        } else if is <= os {
            "IS"
        } else {
            "OS"
        };
        t.row(&[
            e.name.to_string(),
            format!("{}", ws / 1000),
            format!("{}", is / 1000),
            format!("{}", os / 1000),
            best.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("WS wins {ws_wins}/12 on raw cycles; OS/IS win the fold-overhead-bound nets \
(batch-1 FC and short-stream layers).  The trade-offs show up in SRAM traffic below: OS keeps \
partial sums in PE registers (minimal OFMap traffic) but re-streams WEIGHTS once per Sr-fold; \
WS single-passes weights but pays OFMap read-modify-write per K-fold.  Which wins depends on \
the layer mix — the Herald heterogeneous-dataflow observation.");

    section("Total SRAM traffic per dataflow (all buffers, M accesses)");
    let mut t = Table::new(&["model", "WS", "IS", "OS", "OS weight re-reads", "WS ofmap R+W"]);
    for e in ZOO {
        let dnn = (e.build)();
        let mut ws = 0u64;
        let mut is = 0u64;
        let mut os = 0u64;
        let mut os_w = 0u64;
        let mut ws_o = 0u64;
        for l in &dnn.layers {
            let g = l.shape.gemm();
            let a = baseline_layer_timing(geom, g, &bufs).activity;
            ws += a.sram_accesses();
            ws_o += a.ofmap_sram_reads + a.ofmap_sram_writes;
            is += input_stationary_timing(geom, g, &bufs).activity.sram_accesses();
            let a = output_stationary_timing(geom, g, &bufs).activity;
            os += a.sram_accesses();
            os_w += a.weight_sram_reads;
        }
        let f = |x: u64| format!("{:.1}", x as f64 / 1e6);
        t.row(&[e.name.to_string(), f(ws), f(is), f(os), f(os_w), f(ws_o)]);
    }
    println!("{}", t.render());
}
