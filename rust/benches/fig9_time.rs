//! Fig. 9(a)(b) — per-DNN computation time, baseline (sequential single
//! tenant) vs dynamic partitioning, for the heavy (multi-domain) and
//! light (RNN) workload pools.  Prints both allocation policies: `equal`
//! is the paper's literal Partition_Calculation; `widest` is the
//! demand-aware variant (see DESIGN.md §7 and EXPERIMENTS.md).
//!
//! The headline H1 rows (time saving per pool) are printed last.

use mtsa::benchkit::section;
use mtsa::coordinator::scheduler::{AllocPolicy, SchedulerConfig};
use mtsa::report;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::models::{heavy_pool, light_pool};

fn fig(pool: &mtsa::workloads::dnng::WorkloadPool, tag: &str) {
    let cfg = SchedulerConfig::default();
    for (pname, policy) in
        [("widest(demand-aware)", AllocPolicy::WidestToHeaviest), ("equal(paper-literal)", AllocPolicy::EqualShare)]
    {
        let g = report::run_group_with_policy(pool, &cfg, policy);
        section(&format!("Fig 9({tag}) computation time — {} — policy {pname}", pool.name));
        let mut t = Table::new(&["DNN", "baseline done@", "partitioned done@", "saving"]);
        for (name, seq_done) in &g.sequential.completion {
            let dyn_done = g.dynamic.completion[name];
            t.row(&[
                name.clone(),
                seq_done.to_string(),
                dyn_done.to_string(),
                format!("{:+.1}%", report::saving_pct(*seq_done as f64, dyn_done as f64)),
            ]);
        }
        t.row(&[
            "== makespan ==".into(),
            g.sequential.makespan.to_string(),
            g.dynamic.makespan.to_string(),
            format!(
                "{:+.1}%",
                report::saving_pct(g.sequential.makespan as f64, g.dynamic.makespan as f64)
            ),
        ]);
        t.row(&[
            "== mean completion ==".into(),
            format!("{:.0}", report::mean_completion(&g.sequential)),
            format!("{:.0}", report::mean_completion(&g.dynamic)),
            format!(
                "{:+.1}%",
                report::saving_pct(
                    report::mean_completion(&g.sequential),
                    report::mean_completion(&g.dynamic)
                )
            ),
        ]);
        println!("{}", t.render());
    }
}

fn main() {
    fig(&heavy_pool(), "a");
    fig(&light_pool(), "b");

    section("H1 summary (paper: 56% heavy / 44% light computation-time saving)");
    let cfg = SchedulerConfig::default();
    let model = mtsa::energy::EnergyModel::default_128();
    for pool in [heavy_pool(), light_pool()] {
        for (pname, policy) in
            [("widest", AllocPolicy::WidestToHeaviest), ("equal", AllocPolicy::EqualShare)]
        {
            let g = report::run_group_with_policy(&pool, &cfg, policy);
            let h = report::headline(&g, &model);
            println!(
                "{:24} policy={:6} makespan saving {:+6.1}%   mean-completion saving {:+6.1}%   util {:.1}% -> {:.1}%",
                pool.name, pname, h.makespan_saving_pct, h.mean_completion_saving_pct,
                100.0 * h.seq_utilization, 100.0 * h.dyn_utilization
            );
        }
    }
}
