//! Table 1 — the simulation workloads: model, domain, group, plus the
//! derived layer counts and MAC totals the rest of the evaluation uses.

use mtsa::benchkit::section;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::models::ZOO;

fn main() {
    section("Table 1: simulation workloads (12 PyTorch-published networks)");
    let mut t = Table::new(&["model", "domain", "group", "layers", "GMACs", "max GEMM M", "max GEMM K"]);
    for e in ZOO {
        let dnn = (e.build)();
        let max_m = dnn.layers.iter().map(|l| l.shape.gemm().m).max().unwrap();
        let max_k = dnn.layers.iter().map(|l| l.shape.gemm().k).max().unwrap();
        t.row(&[
            e.name.to_string(),
            e.domain.to_string(),
            e.group.tag().to_string(),
            dnn.layers.len().to_string(),
            format!("{:.3}", dnn.total_macs() as f64 / 1e9),
            max_m.to_string(),
            max_k.to_string(),
        ]);
    }
    println!("{}", t.render());
}
