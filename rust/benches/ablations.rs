//! Ablations A1–A3 (DESIGN.md §7): what each design choice buys.
//!
//! - **A1 merging** — dynamic (merge-on-free) vs static equal partitions
//!   vs sequential.
//! - **A2 feed-bus policy** — independent per-partition feeds (paper
//!   model) vs interleaved shared row wires (conservative physical model).
//! - **A3 granularity** — minimum partition width 8/16/32/64.
//! - **A4 allocation policy** — demand-aware widest-to-heaviest vs the
//!   literal equal-share Partition_Calculation.
//! - **A5 scale-out** — one partitioned array vs 2/4/8 independent chips
//!   at equal silicon (the paper's §5 related-work alternative).

use mtsa::benchkit::section;
use mtsa::coordinator::baseline::SequentialBaseline;
use mtsa::coordinator::multi_array::MultiArrayBank;
use mtsa::coordinator::scheduler::{AllocPolicy, DynamicScheduler, FeedModel, SchedulerConfig};
use mtsa::coordinator::static_part::StaticPartitioning;
use mtsa::report;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::models::{heavy_pool, light_pool};

fn main() {
    let pools = [heavy_pool(), light_pool()];
    let base_cfg = SchedulerConfig::default();

    section("A1: partition merging — sequential vs static-equal vs dynamic");
    let mut t = Table::new(&["pool", "sequential", "static-equal", "dynamic", "dyn vs static"]);
    for pool in &pools {
        let seq = SequentialBaseline::new(base_cfg.clone()).run(pool);
        let stat = StaticPartitioning::new(base_cfg.clone()).run(pool);
        let dynm = DynamicScheduler::new(base_cfg.clone()).run(pool);
        t.row(&[
            pool.name.clone(),
            seq.makespan.to_string(),
            stat.makespan.to_string(),
            dynm.makespan.to_string(),
            format!("{:+.1}%", report::saving_pct(stat.makespan as f64, dynm.makespan as f64)),
        ]);
    }
    println!("{}", t.render());

    section("A2: feed-bus model — independent (paper) vs interleaved (physical)");
    let mut t = Table::new(&["pool", "independent", "interleaved", "penalty"]);
    for pool in &pools {
        let ind = DynamicScheduler::new(base_cfg.clone()).run(pool);
        let il = DynamicScheduler::new(SchedulerConfig {
            feed_model: FeedModel::Interleaved,
            ..base_cfg.clone()
        })
        .run(pool);
        t.row(&[
            pool.name.clone(),
            ind.makespan.to_string(),
            il.makespan.to_string(),
            format!("{:+.1}%", report::saving_pct(il.makespan as f64, ind.makespan as f64)),
        ]);
    }
    println!("{}", t.render());

    section("A3: partition granularity — minimum width");
    let mut t = Table::new(&["pool", "min 8", "min 16", "min 32", "min 64"]);
    for pool in &pools {
        let mut cells = vec![pool.name.clone()];
        for mw in [8u64, 16, 32, 64] {
            let m = DynamicScheduler::new(SchedulerConfig { min_width: mw, ..base_cfg.clone() })
                .run(pool);
            cells.push(m.makespan.to_string());
        }
        t.row(&cells);
    }
    println!("{}", t.render());

    section("A5: intra-array partitioning vs chip-granularity scale-out (equal silicon)");
    let mut t = Table::new(&["pool", "1x128x128 partitioned", "2x(128x64) chips", "4x(128x32) chips", "8x(128x16) chips"]);
    for pool in &pools {
        let mut cells = vec![pool.name.clone()];
        cells.push(DynamicScheduler::new(base_cfg.clone()).run(pool).makespan.to_string());
        for n in [2usize, 4, 8] {
            cells.push(MultiArrayBank::split_of(&base_cfg, n).run(pool).makespan.to_string());
        }
        t.row(&cells);
    }
    println!("{}", t.render());

    section("A4: allocation policy — widest-to-heaviest vs equal-share (makespan / mean completion)");
    let mut t = Table::new(&["pool", "widest makespan", "equal makespan", "widest mean-compl", "equal mean-compl"]);
    for pool in &pools {
        let w = report::run_group_with_policy(pool, &base_cfg, AllocPolicy::WidestToHeaviest);
        let e = report::run_group_with_policy(pool, &base_cfg, AllocPolicy::EqualShare);
        t.row(&[
            pool.name.clone(),
            w.dynamic.makespan.to_string(),
            e.dynamic.makespan.to_string(),
            format!("{:.0}", report::mean_completion(&w.dynamic)),
            format!("{:.0}", report::mean_completion(&e.dynamic)),
        ]);
    }
    println!("{}", t.render());
}
