//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build environment is fully offline (no crates.io), so this in-tree
//! path dependency provides the subset of the real crate's API that `mtsa`
//! uses, with the same semantics:
//!
//! - [`Error`]: an opaque error with a context chain; `Display` prints the
//!   outermost message, `{:#}` prints the whole chain joined by `": "`,
//!   `Debug` prints the anyhow-style `Caused by:` listing.
//! - [`Result<T>`]: alias with `Error` as the default error type.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` (for any
//!   `std::error::Error` source or an existing [`Error`]) and on `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Swapping back to the real crate is a one-line change in `Cargo.toml`;
//! no call site depends on anything beyond the real crate's API.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of context messages.
///
/// The chain is ordered outermost-first: index 0 is the most recently
/// attached context, the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes the blanket impls below
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    use super::Error;

    /// Anything that can be absorbed into an [`Error`] chain.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to errors (mirrors `anyhow::Context`).
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_display() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest.json")
            .context("loading artifacts")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading artifacts");
        assert_eq!(
            format!("{e:#}"),
            "loading artifacts: reading manifest.json: file missing"
        );
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("loading artifacts"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        let v: Option<u32> = Some(7);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero is not allowed");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too large: 11");
        let e = anyhow!("custom {}", 5);
        assert_eq!(format!("{e}"), "custom 5");
        let msg = String::from("from a value");
        let e = anyhow!(msg);
        assert_eq!(format!("{e}"), "from a value");
    }

    #[test]
    fn context_on_error_result() {
        fn inner() -> Result<()> {
            bail!("root");
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 2);
    }
}
