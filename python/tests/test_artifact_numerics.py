"""Every AOT variant, executed numerically against its oracle.

`test_aot.py` checks the emitted HLO text; this file checks that the very
functions being lowered compute the right numbers at the artifact shapes —
the last line of defence before the rust runtime consumes them (which
re-verifies through PJRT in rust/tests/runtime_pjrt.rs).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import partitioned_ws as k
from compile.kernels import ref

S, K, C = model.ARRAY_S, model.ARRAY_K, model.ARRAY_C


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _tenant_setup(rng, p):
    width = C // p
    ct = jnp.asarray(np.repeat(np.arange(p), width), jnp.int32)
    x = _rand(rng, p, S, K)
    w = _rand(rng, K, C)
    acc = _rand(rng, S, C)
    return x, w, ct, acc


@pytest.mark.parametrize("p", [1, 2, 4, 8])
def test_pws_variant_matches_ref(p):
    rng = np.random.default_rng(p)
    x, w, ct, acc = _tenant_setup(rng, p)
    (got,) = model.pws_step(x, w, k.tenant_mask(ct, p), acc)
    want = ref.partitioned_ws_ref(x, w, ct, acc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_gemm_baseline_matches_ref():
    rng = np.random.default_rng(100)
    x, w, acc = _rand(rng, S, K), _rand(rng, K, C), _rand(rng, S, C)
    (got,) = model.gemm_baseline_step(x, w, acc)
    want = ref.single_tenant_ref(x, w, acc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_fused_variant_matches_composition():
    rng = np.random.default_rng(101)
    x, w, ct, acc = _tenant_setup(rng, 4)
    bias = _rand(rng, C)
    mask = k.tenant_mask(ct, 4)
    (fused,) = model.pws_fused_step(x, w, mask, acc, bias)
    (partial,) = model.pws_step(x, w, mask, acc)
    (unfused,) = model.drain_step(partial, bias, activation="relu")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["relu", "none"])
def test_drain_variants_match_ref(act):
    rng = np.random.default_rng(102)
    y, b = _rand(rng, S, C), _rand(rng, C)
    (got,) = model.drain_step(y, b, activation=act)
    want = ref.drain_postproc_ref(y, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_unassigned_columns_at_artifact_shape():
    """A half-empty p=8 step: unowned columns drain acc exactly."""
    rng = np.random.default_rng(103)
    x = _rand(rng, 8, S, K)
    w = _rand(rng, K, C)
    acc = _rand(rng, S, C)
    ct = np.full(C, -1, np.int32)
    ct[: C // 2] = np.repeat(np.arange(4), C // 8)  # only 4 of 8 lanes own columns
    ct = jnp.asarray(ct)
    (got,) = model.pws_step(x, w, k.tenant_mask(ct, 8), acc)
    got = np.asarray(got)
    np.testing.assert_array_equal(got[:, C // 2 :], np.asarray(acc)[:, C // 2 :])
    want = ref.partitioned_ws_ref(x, w, ct, acc)
    np.testing.assert_allclose(got, np.asarray(want), rtol=5e-4, atol=5e-4)
