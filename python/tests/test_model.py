"""L2 correctness: model composition, fold chaining, packing, conv lowering."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import partitioned_ws as k
from compile.kernels import ref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


class TestFoldChaining:
    @pytest.mark.parametrize("ktot", [16, 100, 128, 300, 400])
    def test_matches_monolithic_gemm(self, ktot):
        rng = np.random.default_rng(ktot)
        x = _rand(rng, 24, ktot)
        w = _rand(rng, ktot, 48)
        got = model.run_layer_folds(x, w, array_k=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=5e-4, atol=5e-4)

    def test_ragged_last_fold_zero_padded(self):
        """K=130 on a 128-tall array: 2-row ragged fold must not corrupt."""
        rng = np.random.default_rng(99)
        x = _rand(rng, 8, 130)
        w = _rand(rng, 130, 16)
        got = model.run_layer_folds(x, w, array_k=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=5e-4, atol=5e-4)


class TestPackTenants:
    def test_two_tenant_pack_layout(self):
        rng = np.random.default_rng(0)
        a = (_rand(rng, 4, 8), _rand(rng, 8, 6))
        b = (_rand(rng, 4, 8), _rand(rng, 8, 10))
        x, w_packed, ct = model.pack_tenants([a, b], c_array=32)
        assert x.shape == (2, 4, 8)
        assert w_packed.shape == (8, 32)
        np.testing.assert_array_equal(np.asarray(ct[:6]), 0)
        np.testing.assert_array_equal(np.asarray(ct[6:16]), 1)
        np.testing.assert_array_equal(np.asarray(ct[16:]), -1)
        np.testing.assert_array_equal(np.asarray(w_packed[:, :6]), np.asarray(a[1]))
        np.testing.assert_array_equal(np.asarray(w_packed[:, 6:16]), np.asarray(b[1]))

    def test_packed_step_recovers_each_tenant_gemm(self):
        """End-to-end L2 semantics: packed partitioned step == per-tenant GEMMs."""
        rng = np.random.default_rng(1)
        tiles = [
            (_rand(rng, 8, 16), _rand(rng, 16, 12)),
            (_rand(rng, 8, 16), _rand(rng, 16, 4)),
            (_rand(rng, 8, 16), _rand(rng, 16, 8)),
        ]
        x, w_packed, ct = model.pack_tenants(tiles, c_array=32)
        mask = k.tenant_mask(ct, 3)
        acc = jnp.zeros((8, 32), jnp.float32)
        (y,) = model.pws_step(x, w_packed, mask, acc)
        c0 = 0
        for p, (xt, wt) in enumerate(tiles):
            wc = wt.shape[1]
            np.testing.assert_allclose(
                np.asarray(y[:, c0 : c0 + wc]),
                np.asarray(xt @ wt),
                rtol=2e-4,
                atol=2e-4,
            )
            c0 += wc

    def test_overflow_rejected(self):
        rng = np.random.default_rng(2)
        tiles = [(_rand(rng, 2, 4), _rand(rng, 4, 20))] * 2
        with pytest.raises(AssertionError):
            model.pack_tenants(tiles, c_array=32)


class TestConvAsGemm:
    @pytest.mark.parametrize(
        "n,c,h,w,m,r,stride,pad",
        [
            (1, 3, 8, 8, 4, 3, 1, 1),
            (2, 8, 16, 16, 8, 3, 2, 1),
            (1, 1, 5, 5, 2, 5, 1, 0),
            (1, 4, 7, 9, 3, 1, 1, 0),  # 1x1 conv
            (2, 2, 11, 11, 6, 3, 3, 0),
        ],
    )
    def test_matches_lax_conv(self, n, c, h, w, m, r, stride, pad):
        rng = np.random.default_rng(h * w + m)
        ifm = _rand(rng, n, c, h, w)
        wt = _rand(rng, m, c, r, r)
        xg, wg, oshape = model.conv2d_as_gemm(ifm, wt, stride=stride, pad=pad)
        assert xg.shape == (oshape[0] * oshape[2] * oshape[3], c * r * r)
        out = (
            (xg @ wg)
            .reshape(oshape[0], oshape[2], oshape[3], oshape[1])
            .transpose(0, 3, 1, 2)
        )
        want = jax.lax.conv_general_dilated(
            ifm, wt, (stride, stride), [(pad, pad), (pad, pad)]
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 2),
        c=st.integers(1, 6),
        hw=st.integers(4, 12),
        m=st.integers(1, 6),
        r=st.sampled_from([1, 3]),
        stride=st.sampled_from([1, 2]),
        pad=st.sampled_from([0, 1]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_lax_conv(self, n, c, hw, m, r, stride, pad, seed):
        rng = np.random.default_rng(seed)
        ifm = _rand(rng, n, c, hw, hw)
        wt = _rand(rng, m, c, r, r)
        xg, wg, oshape = model.conv2d_as_gemm(ifm, wt, stride=stride, pad=pad)
        out = (
            (xg @ wg)
            .reshape(oshape[0], oshape[2], oshape[3], oshape[1])
            .transpose(0, 3, 1, 2)
        )
        want = jax.lax.conv_general_dilated(
            ifm, wt, (stride, stride), [(pad, pad), (pad, pad)]
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


class TestArtifactFunctions:
    def test_gemm_baseline_step(self):
        rng = np.random.default_rng(3)
        x, w, acc = _rand(rng, 8, 8), _rand(rng, 8, 8), _rand(rng, 8, 8)
        (y,) = model.gemm_baseline_step(x, w, acc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(acc + x @ w), rtol=1e-5, atol=1e-5)

    def test_pws_fused_step(self):
        rng = np.random.default_rng(4)
        ct = jnp.asarray(np.repeat(np.arange(4), 8), jnp.int32)
        mask = k.tenant_mask(ct, 4)
        x = _rand(rng, 4, 8, 16)
        w = _rand(rng, 16, 32)
        acc = _rand(rng, 8, 32)
        bias = _rand(rng, 32)
        (y,) = model.pws_fused_step(x, w, mask, acc, bias)
        want = ref.drain_postproc_ref(
            ref.partitioned_ws_ref(x, w, ct, acc), bias, "relu"
        )
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_variant_table_shapes(self):
        variants = model.aot_variants()
        assert set(variants) == {
            "pws_p1", "pws_p2", "pws_p4", "pws_p8",
            "pws_fused_p4", "gemm_baseline", "drain_relu", "drain_none",
        }
        for name, (fn, specs) in variants.items():
            out = jax.eval_shape(fn, *specs)
            assert isinstance(out, tuple) and len(out) == 1, name
            assert out[0].shape == (model.ARRAY_S, model.ARRAY_C), name
