"""AOT emission sanity: HLO text is well-formed and manifest-consistent.

These tests exercise the interchange contract with the rust runtime without
needing the rust toolchain: the emitted text must be parseable HLO with an
ENTRY computation, tuple return, and parameter shapes matching the manifest.
"""

import json
import os
import re

import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _lower(name):
    fn, specs = model.aot_variants()[name]
    return aot.lower_variant(name, fn, specs)


class TestHloEmission:
    def test_entry_and_tuple_return(self):
        text = _lower("gemm_baseline")
        assert "ENTRY" in text
        assert re.search(r"ROOT\s+\S+\s*=\s*\(f32\[128,128\]", text), (
            "entry must return a tuple of f32[128,128]"
        )

    def test_parameter_shapes_match_specs(self):
        text = _lower("pws_p4")
        # x[4,128,128], w[128,128], mask[4,128], acc[128,128]
        for shape in ("f32[4,128,128]", "f32[128,128]", "f32[4,128]"):
            assert shape in text, f"missing parameter shape {shape}"

    def test_no_custom_calls(self):
        """interpret=True pallas must lower to plain HLO (CPU-executable)."""
        for name in ("pws_p1", "pws_p8", "drain_relu"):
            text = _lower(name)
            assert "custom-call" not in text.lower(), (
                f"{name} contains a custom-call; CPU PJRT cannot run it"
            )

    def test_deterministic_lowering(self):
        assert _lower("gemm_baseline") == _lower("gemm_baseline")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    def _manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_file_exists(self):
        m = self._manifest()
        assert m["schema"] == 1
        for a in m["artifacts"]:
            assert os.path.exists(os.path.join(ARTIFACTS, a["file"])), a["file"]

    def test_covers_all_variants(self):
        m = self._manifest()
        names = {a["name"] for a in m["artifacts"]}
        assert names == set(model.aot_variants().keys())

    def test_array_geometry(self):
        m = self._manifest()
        assert m["array"] == {"s": 128, "k": 128, "c": 128}

    def test_input_signatures(self):
        m = self._manifest()
        variants = model.aot_variants()
        for a in m["artifacts"]:
            specs = variants[a["name"]][1]
            assert len(a["inputs"]) == len(specs)
            for got, spec in zip(a["inputs"], specs):
                assert tuple(got["shape"]) == spec.shape
                assert got["dtype"] == "float32"
