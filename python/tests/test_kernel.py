"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer.  Hypothesis
sweeps shapes, partition counts, block sizes and column→tenant maps; a fixed
battery covers the degenerate cases the sweep may under-sample.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import partitioned_ws as k
from compile.kernels import ref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _check_pws(rng, num_p, s, kk, c, bs, bc, bk, tenant_map=None):
    x = _rand(rng, num_p, s, kk)
    w = _rand(rng, kk, c)
    acc = _rand(rng, s, c)
    if tenant_map is None:
        tenant_map = rng.integers(-1, num_p, size=(c,))
    ct = jnp.asarray(tenant_map, jnp.int32)
    mask = k.tenant_mask(ct, num_p)
    got = k.partitioned_ws_matmul(x, w, mask, acc, block_s=bs, block_c=bc, block_k=bk)
    want = ref.partitioned_ws_ref(x, w, ct, acc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Fixed battery
# ---------------------------------------------------------------------------


class TestPartitionedWsFixed:
    def test_single_partition_is_plain_gemm(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, 1, 32, 48)
        w = _rand(rng, 48, 64)
        acc = jnp.zeros((32, 64), jnp.float32)
        ct = jnp.zeros((64,), jnp.int32)
        got = k.partitioned_ws_matmul(x, w, k.tenant_mask(ct, 1), acc)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(x[0] @ w), rtol=1e-4, atol=1e-4
        )

    def test_two_equal_partitions(self):
        rng = np.random.default_rng(2)
        ct = np.repeat([0, 1], 16)
        _check_pws(rng, 2, 16, 16, 32, 8, 8, 8, tenant_map=ct)

    def test_unassigned_columns_pass_acc_through(self):
        """Columns owned by no tenant must drain exactly `acc` (Mul_En=0)."""
        rng = np.random.default_rng(3)
        x = _rand(rng, 2, 8, 8)
        w = _rand(rng, 8, 16)
        acc = _rand(rng, 8, 16)
        ct = jnp.asarray([-1] * 16, jnp.int32)
        got = k.partitioned_ws_matmul(x, w, k.tenant_mask(ct, 2), acc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(acc), rtol=0, atol=0)

    def test_acc_chaining_equals_monolithic(self):
        """Two K-folds chained through acc == one full-K computation."""
        rng = np.random.default_rng(4)
        num_p, s, c = 2, 8, 16
        ct = jnp.asarray(np.repeat([0, 1], 8), jnp.int32)
        mask = k.tenant_mask(ct, num_p)
        x = _rand(rng, num_p, s, 32)
        w = _rand(rng, 32, c)
        zero = jnp.zeros((s, c), jnp.float32)
        y1 = k.partitioned_ws_matmul(x[:, :, :16], w[:16], mask, zero)
        y2 = k.partitioned_ws_matmul(x[:, :, 16:], w[16:], mask, y1)
        want = ref.partitioned_ws_ref(x, w, ct, zero)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_interleaved_tenant_map(self):
        """Tenant ownership need not be contiguous for correctness."""
        rng = np.random.default_rng(5)
        ct = np.arange(32) % 4
        _check_pws(rng, 4, 8, 8, 32, 8, 8, 8, tenant_map=ct)

    def test_ragged_blocks(self):
        """Shapes that do not divide the block sizes still work (padding)."""
        rng = np.random.default_rng(6)
        _check_pws(rng, 3, 10, 14, 22, 8, 8, 8)

    def test_partition_isolation(self):
        """Perturbing tenant B's stream must not change tenant A's columns."""
        rng = np.random.default_rng(7)
        num_p, s, kk, c = 2, 8, 8, 16
        ct = jnp.asarray(np.repeat([0, 1], 8), jnp.int32)
        mask = k.tenant_mask(ct, num_p)
        w = _rand(rng, kk, c)
        acc = jnp.zeros((s, c), jnp.float32)
        x = _rand(rng, num_p, s, kk)
        y_before = k.partitioned_ws_matmul(x, w, mask, acc)
        x_perturbed = x.at[1].add(_rand(rng, s, kk))
        y_after = k.partitioned_ws_matmul(x_perturbed, w, mask, acc)
        # Tenant 0's columns (0..8) are bit-identical; tenant 1's moved.
        np.testing.assert_array_equal(
            np.asarray(y_before[:, :8]), np.asarray(y_after[:, :8])
        )
        assert not np.allclose(np.asarray(y_before[:, 8:]), np.asarray(y_after[:, 8:]))

    def test_mxu_shaped_tile(self):
        """The artifact shape itself: P=4, S=K=C=128, 128-blocks."""
        rng = np.random.default_rng(8)
        ct = np.repeat([0, 1, 2, 3], 32)
        _check_pws(rng, 4, 128, 128, 128, 128, 128, 128, tenant_map=ct)


class TestTenantMask:
    def test_onehot(self):
        ct = jnp.asarray([0, 0, 1, 2, -1], jnp.int32)
        m = np.asarray(k.tenant_mask(ct, 3))
        assert m.shape == (3, 5)
        np.testing.assert_array_equal(m[0], [1, 1, 0, 0, 0])
        np.testing.assert_array_equal(m[1], [0, 0, 1, 0, 0])
        np.testing.assert_array_equal(m[2], [0, 0, 0, 1, 0])

    def test_columns_sum_to_at_most_one(self):
        rng = np.random.default_rng(9)
        ct = jnp.asarray(rng.integers(-1, 4, size=64), jnp.int32)
        m = np.asarray(k.tenant_mask(ct, 4))
        assert (m.sum(axis=0) <= 1).all()


class TestDrainPostproc:
    @pytest.mark.parametrize("act", ["none", "relu", "gelu", "tanh", "sigmoid"])
    def test_matches_ref(self, act):
        rng = np.random.default_rng(10)
        y = _rand(rng, 24, 40)
        b = _rand(rng, 40)
        got = k.drain_postproc(y, b, activation=act, block_s=8, block_c=16)
        want = ref.drain_postproc_ref(y, b, act)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_rejects_unknown_activation(self):
        y = jnp.zeros((4, 4), jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        with pytest.raises(ValueError):
            k.drain_postproc(y, b, activation="swish?")


# ---------------------------------------------------------------------------
# Hypothesis sweep
# ---------------------------------------------------------------------------

_sizes = st.integers(min_value=1, max_value=40)
_blocks = st.sampled_from([4, 8, 16, 32])


class TestPartitionedWsHypothesis:
    @settings(max_examples=40, deadline=None)
    @given(
        num_p=st.integers(min_value=1, max_value=6),
        s=_sizes,
        kk=_sizes,
        c=_sizes,
        bs=_blocks,
        bc=_blocks,
        bk=_blocks,
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref(self, num_p, s, kk, c, bs, bc, bk, seed):
        rng = np.random.default_rng(seed)
        _check_pws(rng, num_p, s, kk, c, bs, bc, bk)

    @settings(max_examples=20, deadline=None)
    @given(
        s=_sizes,
        c=_sizes,
        act=st.sampled_from(["none", "relu", "tanh"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_drain_matches_ref(self, s, c, act, seed):
        rng = np.random.default_rng(seed)
        y = _rand(rng, s, c)
        b = _rand(rng, c)
        got = k.drain_postproc(y, b, activation=act, block_s=8, block_c=8)
        want = ref.drain_postproc_ref(y, b, act)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
