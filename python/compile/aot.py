"""AOT lowering: jax → HLO text artifacts for the rust PJRT runtime.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --outdir ../artifacts

Emits one ``<name>.hlo.txt`` per variant in ``model.aot_variants()`` plus a
``manifest.json`` describing the I/O signature of each artifact, which
``rust/src/runtime`` parses to type-check calls.

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  Lowered with ``return_tuple=True``;
the rust side unwraps with ``to_tuple1()``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name, fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", help="lower a single variant by name")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    variants = model.aot_variants()
    if args.only:
        variants = {args.only: variants[args.only]}

    manifest = {
        "schema": 1,
        "array": {"s": model.ARRAY_S, "k": model.ARRAY_K, "c": model.ARRAY_C},
        "artifacts": [],
    }
    for name, (fn, specs) in sorted(variants.items()):
        text = lower_variant(name, fn, specs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "sha256_16": digest,
                "inputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
                ],
                "num_outputs": 1,
            }
        )
        print(f"  lowered {name:<16} -> {fname} ({len(text)} chars)")

    if not args.only:
        with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        print(f"wrote {args.outdir}/manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
