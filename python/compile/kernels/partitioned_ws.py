"""L1 — Partitioned weight-stationary matmul as a Pallas kernel.

This is the compute hot-spot of the paper (Reshadi & Gregg, PDP'23): a single
weight-stationary systolic array whose columns are *vertically partitioned*
among P concurrent tenants.  The packed weight matrix ``w[K, C]`` holds every
tenant's weight tile in its own contiguous column range; ``col_tenant[C]``
says which tenant owns each column.  Each tenant streams its own IFMap rows
``x[p, S, K]`` across the *whole* array (the feed wire passes through foreign
partitions), and the per-PE ``Mul_En`` tri-state gate of Fig. 7 ensures a
column only accumulates products of its owner's stream.

Kernel semantics (the Mul_En gate written as a mask):

    y[s, c] = acc[s, c] + sum_k x[col_tenant[c], s, k] * w[k, c]

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's load/feed/drain
SRAM buffers become VMEM blocks staged by BlockSpec; the weight tile is held
in VMEM across the whole S-stream loop (weight-stationary by construction);
the tri-state gate becomes a per-column tenant mask applied as a vector
select on the MXU product — no gather, no scatter.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and correctness (vs ``ref.py``) is the build-time contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(n: int, b: int) -> int:
    return ((n + b - 1) // b) * b


def _pws_kernel(x_ref, w_ref, mask_ref, acc_ref, o_ref, *, num_partitions, k_blocks):
    """One (S-block, C-block, K-block) grid step.

    x_ref    [P, Sb, Kb]  every tenant's feed-stream block (same K range)
    w_ref    [Kb, Cb]     packed stationary weight block
    mask_ref [P, Cb]      Mul_En plane: 1.0 where tenant p owns the column
    acc_ref  [Sb, Cb]     incoming partial sums (drain-chain input)
    o_ref    [Sb, Cb]     output block, accumulated across the K grid dim
    """
    k = pl.program_id(2)

    # First K step seeds the output with the incoming partial sums; later
    # steps accumulate in place (the output block index map is constant in k,
    # so the block stays resident in VMEM across the reduction).
    @pl.when(k == 0)
    def _seed():
        o_ref[...] = acc_ref[...]

    w = w_ref[...]
    # Static unroll over partitions: P is tiny (<= 16).  Each step is an
    # MXU-shaped matmul followed by the Mul_En column select.
    for p in range(num_partitions):
        xp = x_ref[p]
        prod = jnp.dot(xp, w, preferred_element_type=jnp.float32)
        o_ref[...] += prod * mask_ref[p][None, :]


def partitioned_ws_matmul(
    x: jax.Array,
    w: jax.Array,
    mask: jax.Array,
    acc: jax.Array,
    *,
    block_s: int = 128,
    block_c: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Partitioned weight-stationary GEMM.

    Args:
      x:    [P, S, K] float32 — per-tenant feed streams.
      w:    [K, C]    float32 — packed stationary weights (all partitions).
      mask: [P, C]    float32 — one-hot Mul_En plane (mask[p, c] = 1.0 iff
            column c belongs to tenant p).  Precomputed at L2 from the
            integer ``col_tenant`` map so the kernel does no integer compare.
      acc:  [S, C]    float32 — incoming partial sums (zeros for the first
            K-fold; lets the rust coordinator chain folds).

    Returns:
      y: [S, C] float32 with y = acc + sum_p (x[p] @ w) * mask[p].
    """
    num_p, s, k = x.shape
    k2, c = w.shape
    assert k2 == k, f"K mismatch: x has {k}, w has {k2}"
    assert mask.shape == (num_p, c), f"mask shape {mask.shape} != {(num_p, c)}"
    assert acc.shape == (s, c), f"acc shape {acc.shape} != {(s, c)}"

    block_s = min(block_s, s)
    block_c = min(block_c, c)
    block_k = min(block_k, k)

    # Pad every operand up to a block multiple: interpret-mode Pallas fills
    # out-of-bounds block reads with NaN (by design, to surface exactly this
    # hazard), and a NaN entering the MXU product poisons valid rows.  The
    # physical array does the same thing — ragged folds are zero-padded into
    # the load registers (see sim::dataflow's ragged-fold handling).
    sp, cp, kp = (_round_up(s, block_s), _round_up(c, block_c), _round_up(k, block_k))
    if (sp, cp, kp) != (s, c, k):
        x = jnp.pad(x, ((0, 0), (0, sp - s), (0, kp - k)))
        w = jnp.pad(w, ((0, kp - k), (0, cp - c)))
        mask = jnp.pad(mask, ((0, 0), (0, cp - c)))
        acc = jnp.pad(acc, ((0, sp - s), (0, cp - c)))
    grid = (pl.cdiv(sp, block_s), pl.cdiv(cp, block_c), pl.cdiv(kp, block_k))

    kernel = functools.partial(
        _pws_kernel, num_partitions=num_p, k_blocks=grid[2]
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Every tenant's stream block for this (s, k) tile; P is not
            # blocked (it is the static unroll dimension).
            pl.BlockSpec((num_p, block_s, block_k), lambda i, j, kk: (0, i, kk)),
            # Stationary weight block for this (k, c) tile.
            pl.BlockSpec((block_k, block_c), lambda i, j, kk: (kk, j)),
            # Mul_En plane depends only on the column block.
            pl.BlockSpec((num_p, block_c), lambda i, j, kk: (0, j)),
            # Incoming partial sums: only read at kk == 0 but staged per (i, j).
            pl.BlockSpec((block_s, block_c), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_s, block_c), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((sp, cp), jnp.float32),
        interpret=interpret,
    )(x, w, mask, acc)[:s, :c]


def _drain_kernel(y_ref, bias_ref, o_ref, *, activation):
    """Drain-step post-processing: bias add + activation on the OFMap block."""
    y = y_ref[...] + bias_ref[...][None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation == "sigmoid":
        y = jax.nn.sigmoid(y)
    # "none" falls through
    o_ref[...] = y


def drain_postproc(
    y: jax.Array,
    bias: jax.Array,
    *,
    activation: str = "relu",
    block_s: int = 128,
    block_c: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused bias + activation applied as the OFMap drains to the drain buffer.

    Args:
      y:    [S, C] float32 — drained partial sums.
      bias: [C]    float32 — per-column (i.e. per-output-channel) bias.
      activation: one of "none", "relu", "gelu", "tanh", "sigmoid".

    Returns: [S, C] float32.
    """
    s, c = y.shape
    assert bias.shape == (c,), f"bias shape {bias.shape} != {(c,)}"
    if activation not in ("none", "relu", "gelu", "tanh", "sigmoid"):
        raise ValueError(f"unknown activation {activation!r}")

    block_s = min(block_s, s)
    block_c = min(block_c, c)
    grid = (pl.cdiv(s, block_s), pl.cdiv(c, block_c))
    kernel = functools.partial(_drain_kernel, activation=activation)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_c,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_s, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, c), jnp.float32),
        interpret=interpret,
    )(y, bias)


def tenant_mask(col_tenant: jax.Array, num_partitions: int) -> jax.Array:
    """Expand an integer column→tenant map into the float Mul_En plane.

    mask[p, c] = 1.0 iff col_tenant[c] == p.  Columns with tenant id >= P
    (e.g. -1 for *unassigned* columns of a partially-filled array) match no
    partition and therefore stay zero — the drained value for those columns
    is exactly ``acc``.
    """
    ids = jnp.arange(num_partitions, dtype=col_tenant.dtype)
    return (col_tenant[None, :] == ids[:, None]).astype(jnp.float32)
