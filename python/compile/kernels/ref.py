"""Pure-jnp oracles for the Pallas kernels.

These are the correctness contracts: ``test_kernel.py`` asserts the Pallas
implementations (interpret=True) match these to float32 tolerance across a
hypothesis sweep of shapes, partition counts and column→tenant maps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def partitioned_ws_ref(
    x: jax.Array, w: jax.Array, col_tenant: jax.Array, acc: jax.Array
) -> jax.Array:
    """Reference partitioned weight-stationary GEMM.

    y[s, c] = acc[s, c] + sum_k x[col_tenant[c], s, k] * w[k, c]

    Columns whose tenant id is outside [0, P) contribute nothing (they model
    unassigned columns; the Mul_En gate never fires for them).
    """
    num_p = x.shape[0]
    # full[p, s, c] = (x[p] @ w)[s, c]
    full = jnp.einsum("psk,kc->psc", x, w)
    onehot = (col_tenant[None, :] == jnp.arange(num_p)[:, None]).astype(x.dtype)
    return acc + jnp.einsum("psc,pc->sc", full, onehot)


def drain_postproc_ref(y: jax.Array, bias: jax.Array, activation: str) -> jax.Array:
    """Reference drain post-processing: bias + activation."""
    out = y + bias[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    elif activation == "tanh":
        out = jnp.tanh(out)
    elif activation == "sigmoid":
        out = jax.nn.sigmoid(out)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def single_tenant_ref(x: jax.Array, w: jax.Array, acc: jax.Array) -> jax.Array:
    """Baseline (unpartitioned) weight-stationary GEMM: acc + x @ w."""
    return acc + x @ w


def im2col_ref(
    ifmap: jax.Array, kh: int, kw: int, stride: int, pad: int
) -> jax.Array:
    """im2col for conv→GEMM lowering (NCHW ifmap → [N*P*Q, C*R*S]).

    Matches ``model.conv2d_as_gemm``'s patch extraction; used as the oracle
    for the conv path.
    """
    n, c, h, w = ifmap.shape
    padded = jnp.pad(ifmap, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    out_h = (h + 2 * pad - kh) // stride + 1
    out_w = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = padded[
                :, :, i : i + stride * out_h : stride, j : j + stride * out_w : stride
            ]
            cols.append(patch.reshape(n, c, out_h * out_w))
    # [N, C*KH*KW, P*Q] with (c, i, j) ordered c-major to match weight reshape
    stacked = jnp.stack(cols, axis=2).reshape(n, c * kh * kw, out_h * out_w)
    return stacked.transpose(0, 2, 1).reshape(n * out_h * out_w, c * kh * kw)
