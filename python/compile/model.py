"""L2 — JAX model of the multi-tenant partitioned systolic array.

The rust coordinator (L3) schedules layers onto vertical partitions of a
single weight-stationary array and executes the actual arithmetic through the
AOT artifacts defined here.  One artifact = one fixed-shape jitted function,
lowered once by ``aot.py`` to HLO text.

The unit of execution is an **array tile step**: the array holds a packed
``[K_tile, C_array]`` weight block (all co-resident tenants' weight columns),
each tenant feeds an ``[S_tile, K_tile]`` stream block, and the step drains an
``[S_tile, C_array]`` block of partial sums.  The rust side chains steps over
K-folds by passing the previous drain back in as ``acc`` — exactly the
fold-by-fold operation of the cycle simulator, so the functional path and the
timing path walk the same schedule.

Everything here calls the L1 Pallas kernels (interpret=True); Python runs
only at build time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import partitioned_ws as k
from .kernels import ref as ref


# ---------------------------------------------------------------------------
# Artifact-facing functions (fixed shapes, AOT-lowered by aot.py)
# ---------------------------------------------------------------------------


def pws_step(x, w, mask, acc):
    """One partitioned weight-stationary array step (the hot path).

    x    [P, S, K]  per-tenant feed streams
    w    [K, C]     packed stationary weights
    mask [P, C]     Mul_En plane (precomputed float one-hot)
    acc  [S, C]     partial sums from the previous K-fold
    →    [S, C]
    """
    return (k.partitioned_ws_matmul(x, w, mask, acc),)


def gemm_baseline_step(x, w, acc):
    """Single-tenant (unpartitioned) weight-stationary step: acc + x @ w.

    This is the baseline datapath the paper compares against; keeping it a
    separate artifact means the baseline run never pays the masking FLOPs.
    """
    return (acc + jnp.dot(x, w, preferred_element_type=jnp.float32),)


def drain_step(y, bias, *, activation: str):
    """Drain-buffer post-processing artifact: bias + activation."""
    return (k.drain_postproc(y, bias, activation=activation),)


def pws_fused_step(x, w, mask, acc, bias):
    """Fused variant: partitioned step + relu drain in one artifact.

    Used by the serving example for last-fold steps so the OFMap makes a
    single trip through the drain buffer.
    """
    y = k.partitioned_ws_matmul(x, w, mask, acc)
    return (k.drain_postproc(y, bias, activation="relu"),)


# ---------------------------------------------------------------------------
# Model-construction helpers (used by tests and by aot.py's example inputs)
# ---------------------------------------------------------------------------


def conv2d_as_gemm(ifmap, weights, stride: int = 1, pad: int = 0):
    """Lower a conv layer to the GEMM the systolic array actually runs.

    ifmap   [N, C, H, W]
    weights [M, C, R, S]
    Returns (x_gemm [N*P*Q, C*R*S], w_gemm [C*R*S, M], out_shape (N, M, P, Q)).
    """
    n, c, h, w_ = ifmap.shape
    m, c2, r, s = weights.shape
    assert c == c2, f"channel mismatch {c} vs {c2}"
    x_gemm = ref.im2col_ref(ifmap, r, s, stride, pad)
    w_gemm = weights.reshape(m, c * r * s).T
    out_h = (h + 2 * pad - r) // stride + 1
    out_w = (w_ + 2 * pad - s) // stride + 1
    return x_gemm, w_gemm, (n, m, out_h, out_w)


def run_layer_folds(x, w, *, array_k: int, num_partitions: int = 1):
    """Execute a full [S, K] × [K, C] GEMM by chaining pws_step over K-folds.

    Mirrors what the rust coordinator does with the artifact: split K into
    array-height folds, run one step per fold, thread ``acc`` through.  Used
    by tests to prove fold-chaining reproduces the monolithic matmul.
    """
    s, ktot = x.shape
    kdim, c = w.shape
    assert kdim == ktot
    col_tenant = jnp.zeros((c,), dtype=jnp.int32)
    mask = k.tenant_mask(col_tenant, num_partitions)
    acc = jnp.zeros((s, c), dtype=jnp.float32)
    for k0 in range(0, ktot, array_k):
        k1 = min(k0 + array_k, ktot)
        kw = k1 - k0
        # Pad the ragged last fold up to the artifact's fixed K.
        xf = jnp.zeros((num_partitions, s, array_k), dtype=jnp.float32)
        xf = xf.at[0, :, :kw].set(x[:, k0:k1])
        wf = jnp.zeros((array_k, c), dtype=jnp.float32)
        wf = wf.at[:kw, :].set(w[k0:k1, :])
        (acc,) = pws_step(xf, wf, mask, acc)
    return acc


def pack_tenants(tiles, c_array: int):
    """Pack per-tenant (x_tile [S,K], w_tile [K,w_cols]) into array-wide operands.

    Returns (x [P,S,K], w_packed [K,C], col_tenant [C]) with tenants laid out
    left-to-right in contiguous column partitions, unused columns marked -1.
    Mirrors rust ``runtime::packing``.
    """
    num_p = len(tiles)
    s, kdim = tiles[0][0].shape
    x = jnp.stack([t[0] for t in tiles])
    w_packed = jnp.zeros((kdim, c_array), dtype=jnp.float32)
    col_tenant = -jnp.ones((c_array,), dtype=jnp.int32)
    c0 = 0
    for p, (_, wt) in enumerate(tiles):
        wc = wt.shape[1]
        assert c0 + wc <= c_array, "tenant tiles overflow the array width"
        w_packed = w_packed.at[:, c0 : c0 + wc].set(wt)
        col_tenant = col_tenant.at[c0 : c0 + wc].set(p)
        c0 += wc
    return x, w_packed, col_tenant


# ---------------------------------------------------------------------------
# AOT variant table — the contract with rust/src/runtime (see manifest.json)
# ---------------------------------------------------------------------------

ARRAY_S = 128  # stream-block rows per step
ARRAY_K = 128  # array height (K rows held stationary per fold)
ARRAY_C = 128  # array width (columns, the partitioned dimension)

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def aot_variants():
    """Every artifact to lower: name → (fn, example arg specs).

    Partition counts cover the paper's observed partition ladder on a
    128-wide array: 1 (whole array), 2 (64-col), 4 (32-col), 8 (16-col).
    """
    variants = {}
    for p in (1, 2, 4, 8):
        variants[f"pws_p{p}"] = (
            pws_step,
            (
                _spec(p, ARRAY_S, ARRAY_K),
                _spec(ARRAY_K, ARRAY_C),
                _spec(p, ARRAY_C),
                _spec(ARRAY_S, ARRAY_C),
            ),
        )
    variants["pws_fused_p4"] = (
        pws_fused_step,
        (
            _spec(4, ARRAY_S, ARRAY_K),
            _spec(ARRAY_K, ARRAY_C),
            _spec(4, ARRAY_C),
            _spec(ARRAY_S, ARRAY_C),
            _spec(ARRAY_C),
        ),
    )
    variants["gemm_baseline"] = (
        gemm_baseline_step,
        (
            _spec(ARRAY_S, ARRAY_K),
            _spec(ARRAY_K, ARRAY_C),
            _spec(ARRAY_S, ARRAY_C),
        ),
    )
    for act in ("relu", "none"):
        variants[f"drain_{act}"] = (
            lambda y, b, _act=act: drain_step(y, b, activation=_act),
            (_spec(ARRAY_S, ARRAY_C), _spec(ARRAY_C)),
        )
    return variants
