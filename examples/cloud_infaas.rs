//! Cloud INFaaS scenario (paper §1): an inference-as-a-service endpoint
//! receives a Poisson stream of mixed DNN jobs (zoo networks) and serves
//! them on one 128×128 array.  Compares dynamic partitioning against the
//! sequential baseline on tail latency and throughput.
//!
//! ```bash
//! cargo run --release --example cloud_infaas [seed] [num_jobs]
//! ```

use mtsa::coordinator::baseline::SequentialBaseline;
use mtsa::coordinator::scheduler::AllocPolicy;
use mtsa::coordinator::{DynamicScheduler, RunMetrics, SchedulerConfig};
use mtsa::report;
use mtsa::util::rng::Rng;
use mtsa::util::stats::Summary;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::dnng::WorkloadPool;
use mtsa::workloads::models;

fn turnaround_summary(pool: &WorkloadPool, m: &RunMetrics) -> Summary {
    let samples: Vec<f64> = pool
        .dnns
        .iter()
        .map(|d| (m.completion[&d.name] - d.arrival_cycles) as f64)
        .collect();
    Summary::from_samples(&samples).unwrap()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(7);
    let num_jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let mut rng = Rng::new(seed);

    // A job mix skewed toward small models (the INFaaS reality): NCF and
    // the RNNs dominate request counts; the big CNNs appear occasionally.
    let mix: &[(&str, f64)] = &[
        ("NCF", 0.30),
        ("HandwritingLSTM", 0.15),
        ("DeepVoice", 0.15),
        ("SA_CNN", 0.12),
        ("SA_LSTM", 0.10),
        ("MelodyLSTM", 0.08),
        ("AlphaGoZero", 0.05),
        ("Transformer", 0.03),
        ("AlexNet", 0.02),
    ];

    // Poisson arrivals, mean gap 40k cycles (~57 µs at 0.7 GHz).
    let mut dnns = Vec::new();
    let mut t = 0.0f64;
    for i in 0..num_jobs {
        let roll = rng.gen_f64();
        let mut acc = 0.0;
        let mut pick = mix[0].0;
        for (name, p) in mix {
            acc += p;
            if roll < acc {
                pick = name;
                break;
            }
        }
        let entry = models::by_name(pick).unwrap();
        let mut dnn = (entry.build)();
        dnn.name = format!("{}#{i}", entry.name);
        t += rng.gen_exp(1.0 / 40_000.0);
        dnns.push(dnn.arriving_at(t as u64));
    }
    let pool = WorkloadPool::new("infaas", dnns);

    let cfg = SchedulerConfig::default();
    let equal_cfg =
        SchedulerConfig { alloc_policy: AllocPolicy::EqualShare, ..cfg.clone() };
    let dynamic = DynamicScheduler::new(cfg.clone()).run(&pool);
    let dynamic_eq = DynamicScheduler::new(equal_cfg).run(&pool);
    let sequential = SequentialBaseline::new(cfg.clone()).run(&pool);

    println!(
        "INFaaS stream: {} jobs over {:.1}M cycles (seed {seed})\n",
        num_jobs,
        pool.dnns.last().unwrap().arrival_cycles as f64 / 1e6
    );

    let ds = turnaround_summary(&pool, &dynamic);
    let de = turnaround_summary(&pool, &dynamic_eq);
    let ss = turnaround_summary(&pool, &sequential);
    let mut table =
        Table::new(&["turnaround (cycles)", "sequential FIFO", "dyn widest", "dyn equal-share", "best saving"]);
    let to_c = |c: f64| format!("{:.0}", c);
    for (label, s, d, e) in [
        ("mean", ss.mean, ds.mean, de.mean),
        ("p50", ss.p50, ds.p50, de.p50),
        ("p95", ss.p95, ds.p95, de.p95),
        ("p99", ss.p99, ds.p99, de.p99),
        ("max", ss.max, ds.max, de.max),
    ] {
        table.row(&[
            label.to_string(),
            to_c(s),
            to_c(d),
            to_c(e),
            format!("{:+.1}%", report::saving_pct(s, d.min(e))),
        ]);
    }
    println!("{}", table.render());

    let thru = |m: &RunMetrics| num_jobs as f64 / m.makespan as f64 * 1e6;
    println!(
        "throughput: sequential {:.2} vs dynamic {:.2} jobs/Mcycle ({:+.1}%)",
        thru(&sequential),
        thru(&dynamic),
        report::saving_pct(thru(&sequential), thru(&dynamic)) * -1.0
    );
    println!(
        "makespan:   {} -> {} cycles ({:+.1}%)",
        sequential.makespan,
        dynamic.makespan,
        report::saving_pct(sequential.makespan as f64, dynamic.makespan as f64)
    );
}
