//! Cloud INFaaS scenario (paper §1): an inference-as-a-service endpoint
//! receives a Poisson stream of mixed DNN jobs (zoo networks) — now served
//! by the fleet tier ([`mtsa::fleet`]): a small cluster of 128×128 arrays
//! behind a batching router with SLO classes.  Compares a cluster of
//! dynamically partitioned instances against the same silicon running the
//! sequential baseline on SLO attainment, tail latency and cost per query.
//!
//! ```bash
//! cargo run --release --example cloud_infaas [seed] [num_jobs]
//! ```

use mtsa::coordinator::scheduler::SchedulerConfig;
use mtsa::fleet::{run_fleet, FleetConfig, FleetPolicy, FleetReport, Placement, SloClass};
use mtsa::report;
use mtsa::workloads::generator::{ArrivalProcess, ModelMix};

/// A job mix skewed toward small models (the INFaaS reality): NCF and
/// the RNNs dominate request counts; the big CNNs appear occasionally.
fn infaas_mix() -> ModelMix {
    ModelMix::new(&[
        ("NCF", 0.30),
        ("HandwritingLSTM", 0.15),
        ("DeepVoice", 0.15),
        ("SA_CNN", 0.12),
        ("SA_LSTM", 0.10),
        ("MelodyLSTM", 0.08),
        ("AlphaGoZero", 0.05),
        ("Transformer", 0.03),
        ("AlexNet", 0.02),
    ])
}

fn endpoint(policy: FleetPolicy, requests: usize, seed: u64) -> FleetConfig {
    let sched = SchedulerConfig::default();
    FleetConfig {
        instances: FleetConfig::uniform(4, &sched, policy),
        placement: Placement::LeastLoaded,
        random_k: 2,
        classes: FleetConfig::default_classes(40_000.0),
        slots: 8,
        queue_cap: 64,
        mix: infaas_mix(),
        // Poisson arrivals, mean gap 40k cycles (~57 µs at 0.7 GHz).
        arrival: ArrivalProcess::Poisson { mean_interarrival: 40_000.0 },
        diurnal: None,
        requests,
        seed,
        chunk: 2048,
        tables: None,
    }
}

fn class(r: &FleetReport, c: SloClass) -> &mtsa::fleet::ClassReport {
    r.classes.iter().find(|cr| cr.class == c).expect("all classes reported")
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(7);
    let num_jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let dynamic = run_fleet(&endpoint(FleetPolicy::Dynamic, num_jobs, seed), threads)
        .expect("dynamic fleet");
    let sequential = run_fleet(&endpoint(FleetPolicy::Sequential, num_jobs, seed), threads)
        .expect("sequential fleet");

    println!(
        "INFaaS endpoint: {num_jobs} jobs ({} batches) on 4x 128x128 (seed {seed})\n",
        dynamic.batches
    );
    println!("dynamic partitioning per instance:");
    println!("{}", report::fleet_table(&dynamic).render());
    println!("sequential FIFO per instance (same silicon, same arrivals):");
    println!("{}", report::fleet_table(&sequential).render());

    let dl = class(&dynamic, SloClass::LatencyCritical);
    let sl = class(&sequential, SloClass::LatencyCritical);
    println!(
        "latency-critical: attainment {:.1}% vs {:.1}%, p99 {} vs {} cycles",
        dl.attainment * 100.0,
        sl.attainment * 100.0,
        dl.p99,
        sl.p99,
    );
    println!(
        "fleet: util {:.1}% vs {:.1}%, cost {:.6} vs {:.6} J/query",
        dynamic.utilization * 100.0,
        sequential.utilization * 100.0,
        dynamic.cost_j_per_query,
        sequential.cost_j_per_query,
    );
}
