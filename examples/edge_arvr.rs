//! Edge AR/VR scenario (paper §1): a VR headset runs hand-pose
//! estimation, eye tracking and a voice-command RNN *concurrently* on one
//! small (64×64) systolic array — the multi-DNN edge workload that
//! motivates sharing a single accelerator.
//!
//! ```bash
//! cargo run --release --example edge_arvr
//! ```

use mtsa::coordinator::baseline::SequentialBaseline;
use mtsa::coordinator::{DynamicScheduler, SchedulerConfig};
use mtsa::energy::components::{EnergyModel, Precision};
use mtsa::report;
use mtsa::sim::buffers::BufferConfig;
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::dnng::{Dnn, Layer, WorkloadPool};
use mtsa::workloads::shapes::{LayerKind, LayerShape};

/// Hand-pose CNN: small MobileNet-ish stack over a 96x96 crop.
fn hand_pose() -> Dnn {
    let mut layers = vec![Layer::new(
        "stem",
        LayerKind::Conv,
        LayerShape::conv(1, 3, 96, 96, 16, 3, 3, 2, 1),
    )];
    let mut c = 16;
    let mut sp = 48;
    for i in 0..4 {
        let m = (c * 2).min(128);
        layers.push(Layer::new(
            &format!("conv{i}a"),
            LayerKind::Conv,
            LayerShape::conv(1, c, sp, sp, m, 3, 3, if i % 2 == 0 { 2 } else { 1 }, 1),
        ));
        if i % 2 == 0 {
            sp /= 2;
        }
        c = m;
    }
    layers.push(Layer::new("kp_head", LayerKind::Fc, LayerShape::fc(1, c * sp * sp, 42)));
    Dnn::chain("hand-pose", layers)
}

/// Eye tracker: tiny CNN over two 32x32 eye crops (batch 2).
fn eye_tracker() -> Dnn {
    Dnn::chain(
        "eye-track",
        vec![
            Layer::new("conv1", LayerKind::Conv, LayerShape::conv(2, 1, 32, 32, 16, 5, 5, 2, 2)),
            Layer::new("conv2", LayerKind::Conv, LayerShape::conv(2, 16, 16, 16, 32, 3, 3, 2, 1)),
            Layer::new("gaze_fc", LayerKind::Fc, LayerShape::fc(2, 32 * 8 * 8, 4)),
        ],
    )
}

/// Voice-command GRU over a 50-frame window.
fn voice_rnn() -> Dnn {
    Dnn::chain(
        "voice-cmd",
        vec![
            Layer::new("gru1", LayerKind::Recurrent, LayerShape::recurrent(50, 1, 40, 64, 3)),
            Layer::new("gru2", LayerKind::Recurrent, LayerShape::recurrent(50, 1, 64, 64, 3)),
            Layer::new("cmd_fc", LayerKind::Fc, LayerShape::fc(1, 64, 20)),
        ],
    )
}

fn main() {
    // Edge-sized accelerator: 64x64 PEs, 2 MiB SRAM, int8.
    let geom = ArrayGeometry::new(64, 64);
    let buffers = BufferConfig {
        weight_bytes: 512 << 10,
        ifmap_bytes: 1024 << 10,
        ofmap_bytes: 512 << 10,
        dtype_bytes: 1,
    };
    let cfg = SchedulerConfig {
        geom,
        buffers,
        min_width: 8,
        ..SchedulerConfig::default()
    };
    let model = EnergyModel::build(geom, &buffers, Precision::Int8);

    // One frame of AR/VR work: all three DNNs fire together at vsync.
    let pool = WorkloadPool::new("arvr-frame", vec![hand_pose(), eye_tracker(), voice_rnn()]);

    let dynamic = DynamicScheduler::new(cfg.clone()).run(&pool);
    let sequential = SequentialBaseline::new(cfg.clone()).run(&pool);

    println!("AR/VR frame on a 64x64 edge array ({} layers total)\n", pool.total_layers());
    let mut t = Table::new(&["task", "sequential done@", "concurrent done@", "latency saving"]);
    for (name, seq_done) in &sequential.completion {
        t.row(&[
            name.clone(),
            seq_done.to_string(),
            dynamic.completion[name].to_string(),
            format!("{:+.1}%", report::saving_pct(*seq_done as f64, dynamic.completion[name] as f64)),
        ]);
    }
    println!("{}", t.render());

    let e_dyn = report::total_energy(&dynamic, &model);
    let e_seq = report::total_energy(&sequential, &model);
    println!("frame makespan: {} -> {} cycles ({:+.1}%)",
        sequential.makespan, dynamic.makespan,
        report::saving_pct(sequential.makespan as f64, dynamic.makespan as f64));
    println!("frame energy:   {:.3} -> {:.3} mJ ({:+.1}%)",
        e_seq.total_j() * 1e3, e_dyn.total_j() * 1e3,
        report::saving_pct(e_seq.total_j(), e_dyn.total_j()));
    // At 0.7 GHz, report the frame budget implications.
    let ms = |cycles: u64| cycles as f64 / 0.7e9 * 1e3;
    println!("at 0.7 GHz: {:.2} ms -> {:.2} ms (90 Hz budget is 11.1 ms)",
        ms(sequential.makespan), ms(dynamic.makespan));
}
