//! Fold-boundary preemption vs head-of-line blocking — the pinned
//! bursty light-over-heavy mix of `docs/preemption.md`.
//!
//! One heavy tenant (2 × fc [4000, 1024] × [1024, 64]: 8 K-bands of
//! 4319 cycles per layer) takes the whole 128×128 array at t = 0; six
//! light requests (fc [256, 128] × [128, 32], 543 isolated cycles)
//! burst in at t = 3000..3500, mid-band of the heavy first layer, each
//! carrying a 6× slack-relative deadline (3258 cycles of budget).
//!
//! Without preemption the burst waits out the whole 34552-cycle heavy
//! layer and misses every deadline.  With `preempt = arrival` the heavy
//! layer drains at its next band boundary (cycle 4319), keeps the 64
//! columns its M = 64 demand actually needs, and the burst runs in the
//! freed half — p99 collapses by >90% and the heavy tenant finishes at
//! exactly the same cycle.
//!
//! ```bash
//! cargo run --release --example preemption_bursty
//! ```

use mtsa::coordinator::scenario::{Scenario, ScenarioOutcome, ScenarioSpec};
use mtsa::coordinator::scheduler::{DynamicScheduler, PreemptMode, SchedulerConfig};
use mtsa::report;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::dnng::{Dnn, Layer};
use mtsa::workloads::generator::ArrivalProcess;
use mtsa::workloads::shapes::{LayerKind, LayerShape};

fn fc_chain(name: &str, sr: u64, k: u64, m: u64, n_layers: usize) -> Dnn {
    let layers = (0..n_layers)
        .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(sr, k, m)))
        .collect();
    Dnn::chain(name, layers)
}

fn scenario(cfg: &SchedulerConfig) -> Scenario {
    let mut templates = vec![fc_chain("heavy", 4000, 1024, 64, 2)];
    for _ in 0..6 {
        templates.push(fc_chain("light", 256, 128, 32, 1));
    }
    let spec = ScenarioSpec {
        name: "bursty-light-over-heavy".to_string(),
        arrival: ArrivalProcess::Trace(vec![0, 3000, 3100, 3200, 3300, 3400, 3500]),
        requests: 7,
        seed: 1,
        qos_slack: Some(6.0),
    };
    Scenario::generate(&templates, &spec, cfg)
}

fn light(outcome: &ScenarioOutcome) -> &mtsa::coordinator::metrics::TenantStats {
    outcome.tenants.iter().find(|t| t.tenant == "light").unwrap()
}

fn main() {
    let base = SchedulerConfig::default();
    let sc = scenario(&base);

    let (off_obs, off) = sc.run(&mut DynamicScheduler::new(base.clone()), base.geom);
    let pre_cfg = SchedulerConfig { preempt: PreemptMode::Arrival, ..base.clone() };
    let (pre_obs, pre) = sc.run(&mut DynamicScheduler::new(pre_cfg), base.geom);

    println!("bursty light-over-heavy mix on one 128x128 array (deadline slack 6.0):\n");
    let mut t = Table::new(&["metric", "preempt=off", "preempt=arrival", "saving"]);
    let (lo, lp) = (light(&off), light(&pre));
    t.row(&[
        "light p50 latency (cycles)".into(),
        format!("{:.0}", lo.p50_latency),
        format!("{:.0}", lp.p50_latency),
        format!("{:+.1}%", report::saving_pct(lo.p50_latency, lp.p50_latency)),
    ]);
    t.row(&[
        "light p99 latency (cycles)".into(),
        format!("{:.0}", lo.p99_latency),
        format!("{:.0}", lp.p99_latency),
        format!("{:+.1}%", report::saving_pct(lo.p99_latency, lp.p99_latency)),
    ]);
    t.row(&[
        "light deadline misses".into(),
        format!("{}/6", lo.misses),
        format!("{}/6", lp.misses),
        "".into(),
    ]);
    t.row(&[
        "heavy completion (cycles)".into(),
        off_obs.metrics.completion["heavy#0"].to_string(),
        pre_obs.metrics.completion["heavy#0"].to_string(),
        "".into(),
    ]);
    t.row(&[
        "makespan (cycles)".into(),
        off_obs.metrics.makespan.to_string(),
        pre_obs.metrics.makespan.to_string(),
        "".into(),
    ]);
    println!("{}", t.render());

    println!(
        "preemptions: {} (replayed folds {}, wasted refill cycles {})",
        pre_obs.metrics.preemptions,
        pre_obs.metrics.replayed_folds,
        pre_obs.metrics.wasted_refill_cycles,
    );
    println!(
        "heavy tile trace: {:?} — the 128->64 reshape at the first band boundary",
        pre_obs.metrics.partition_trace("heavy#0"),
    );

    assert!(
        lp.p99_latency * 10.0 < lo.p99_latency,
        "preemption must collapse light p99 ({:.0} vs {:.0})",
        lp.p99_latency,
        lo.p99_latency
    );
    assert!(lp.misses < lo.misses, "preemption must cut the miss count");
    assert!(pre.miss_rate() < off.miss_rate());
    assert_eq!(
        pre_obs.metrics.completion["heavy#0"], off_obs.metrics.completion["heavy#0"],
        "the reshape is free for the heavy tenant on this mix"
    );
}
