//! Scenario sweep: Poisson-arrival heavy-mix serving, across arrival
//! rates and scheduler policies.
//!
//! The paper evaluates two static mixes launched at t=0; this example
//! drives the same Table-1 heavy group as an *arrival-driven, SLA-bound*
//! serving workload (see `docs/scenarios.md`): requests stream in with
//! exponential gaps, each carrying a deadline of `arrival + 3x` its
//! isolated full-array latency.  The sweep fans (rate x policy x feed)
//! across worker threads and reports per-tenant p50/p95/p99 latency and
//! deadline-miss rate per grid point, plus the machine-readable JSON
//! (byte-identical for a fixed seed).
//!
//! ```bash
//! cargo run --release --example sweep_scenarios
//! ```

use mtsa::coordinator::scheduler::{AllocPolicy, FeedModel, SchedulerConfig};
use mtsa::report;
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::sweep::{run_sweep, SweepGrid};

fn main() {
    let grid = SweepGrid {
        mixes: vec!["heavy".to_string()],
        // Batch (the paper's setup), saturating, and relaxed arrivals.
        rates: vec![0.0, 25_000.0, 250_000.0],
        policies: vec![AllocPolicy::WidestToHeaviest, AllocPolicy::EqualShare],
        feeds: vec![FeedModel::Independent, FeedModel::Interleaved],
        geoms: vec![ArrayGeometry::new(128, 128)],
        requests: 10,
        qos_slack: 3.0,
        bursty: None,
        seed: 7,
        ..SweepGrid::default()
    };
    let base = SchedulerConfig::default();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let rows = run_sweep(&grid, &base, threads).expect("sweep");
    println!("{}", report::sweep_table(&grid, &rows).render());

    // Headline: what QoS does dynamic partitioning buy at each rate?
    for row in &rows {
        if row.point.policy != AllocPolicy::WidestToHeaviest
            || row.point.feed != FeedModel::Independent
        {
            continue;
        }
        let dynamic = &row.outcome.overall;
        let seq = &row.seq_outcome.overall;
        let rate = if row.point.mean_interarrival <= 0.0 {
            "batch".to_string()
        } else {
            format!("mean gap {:.0} cyc", row.point.mean_interarrival)
        };
        println!(
            "{rate}: p99 latency {:.0} vs {:.0} cycles sequential ({:+.1}%), \
             miss rate {:.1}% vs {:.1}%",
            dynamic.p99_latency,
            seq.p99_latency,
            report::saving_pct(seq.p99_latency, dynamic.p99_latency),
            100.0 * dynamic.miss_rate(),
            100.0 * seq.miss_rate(),
        );
    }

    let json = report::sweep_json(&grid, &rows).render();
    println!("\nJSON report: {} bytes (seed {} => byte-identical rerun)", json.len(), grid.seed);
}
