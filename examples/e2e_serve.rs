//! END-TO-END driver: multi-tenant inference served on the REAL datapath.
//!
//! Four tenants each run a 3-layer MLP (batch 64) concurrently.  Every
//! layer GEMM is submitted to the coordinator's serving loop, which groups
//! co-resident tenants, packs their weights into the vertical partitions
//! of one physical array step, and executes the AOT-compiled
//! partitioned-weight-stationary artifact (`pws_p{P}`) on the PJRT CPU
//! client — chaining K-folds through the accumulator exactly like the
//! cycle model.  Python is never on this path.
//!
//! Outputs are verified against a host matmul oracle every pass; the run
//! reports grouping behaviour, latency percentiles and throughput.
//! Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mtsa::coordinator::service::{GemmRequest, Service, ServiceHandle};
use mtsa::runtime::{Engine, Tensor};
use mtsa::util::rng::Rng;
use mtsa::util::stats::{fmt_ns, Summary};

/// One tenant's model: 256 -> 32 -> 16 -> 10 MLP with ReLU between layers.
struct TenantModel {
    weights: Vec<Tensor>, // [256x32, 32x16, 16x10]
}

impl TenantModel {
    fn new(rng: &mut Rng) -> TenantModel {
        let dims = [(256, 32), (32, 16), (16, 10)];
        let weights = dims
            .iter()
            .map(|&(k, m)| {
                let scale = 1.0 / (k as f32).sqrt();
                let data: Vec<f32> = (0..k * m).map(|_| (rng.gen_f32() - 0.5) * scale).collect();
                Tensor::new(vec![k, m], data)
            })
            .collect();
        TenantModel { weights }
    }

    /// Host oracle for one full forward pass.
    fn oracle(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        for (i, w) in self.weights.iter().enumerate() {
            h = h.matmul(w);
            if i + 1 < self.weights.len() {
                for v in h.data_mut() {
                    *v = v.max(0.0);
                }
            }
        }
        h
    }
}

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = Arc::new(Engine::load(&dir).expect("engine"));
    let service = Service::new(engine.clone());
    // Dynamic batching: wait up to 3 ms to co-locate tenants in one step.
    let handle = ServiceHandle::spawn(service, 4, Duration::from_millis(3));

    const TENANTS: usize = 4;
    const PASSES: usize = 25;
    const BATCH: usize = 64;

    let mut rng = Rng::new(2024);
    let models: Vec<TenantModel> = (0..TENANTS).map(|_| TenantModel::new(&mut rng)).collect();

    let t0 = Instant::now();
    let handle = Arc::new(handle);
    let mut threads = Vec::new();
    let (lat_tx, lat_rx) = std::sync::mpsc::channel::<u128>();
    for tenant in 0..TENANTS {
        let handle = Arc::clone(&handle);
        let model_weights: Vec<Tensor> = models[tenant].weights.clone();
        let lat_tx = lat_tx.clone();
        let mut trng = Rng::new(1000 + tenant as u64);
        threads.push(std::thread::spawn(move || {
            let mut max_diff = 0.0f32;
            for _pass in 0..PASSES {
                let data: Vec<f32> = (0..BATCH * 256).map(|_| trng.gen_f32() - 0.5).collect();
                let x = Tensor::new(vec![BATCH, 256], data);
                // Forward through the service, layer by layer.
                let mut h = x.clone();
                for (li, w) in model_weights.iter().enumerate() {
                    let rx = handle.submit(GemmRequest { tenant, x: h.clone(), w: w.clone() });
                    let resp = rx.recv().expect("service alive").expect("serve ok");
                    lat_tx.send(resp.latency.as_nanos()).unwrap();
                    h = resp.y;
                    if li + 1 < model_weights.len() {
                        for v in h.data_mut() {
                            *v = v.max(0.0);
                        }
                    }
                }
                // Verify against the host oracle.
                let want = {
                    let m = TenantModel { weights: model_weights.clone() };
                    m.oracle(&x)
                };
                max_diff = max_diff.max(h.max_abs_diff(&want));
            }
            max_diff
        }));
    }
    drop(lat_tx);

    let mut worst = 0.0f32;
    for th in threads {
        worst = worst.max(th.join().expect("tenant thread"));
    }
    let wall = t0.elapsed();
    let latencies: Vec<f64> = lat_rx.iter().map(|n| n as f64).collect();
    let s = Summary::from_samples(&latencies).unwrap();

    let total_gemms = TENANTS * PASSES * 3;
    println!("e2e_serve: {TENANTS} tenants x {PASSES} passes x 3 layers = {total_gemms} GEMMs");
    println!("  numerics: max |dev| vs host oracle = {worst:.2e}  (tolerance 1e-3)");
    assert!(worst < 1e-3, "numerics check failed");
    println!(
        "  latency:  mean {}  p50 {}  p99 {}",
        fmt_ns(s.mean),
        fmt_ns(s.p50),
        fmt_ns(s.p99)
    );
    println!(
        "  wall {:.2?}  throughput {:.0} GEMMs/s  ({} PJRT array steps executed)",
        wall,
        total_gemms as f64 / wall.as_secs_f64(),
        engine.exec_count()
    );
    // Each GEMM needs >= 1 array step (the 256-K first layer needs 2 folds);
    // perfect 4-tenant packing would average 4 GEMMs per step-group.
    println!(
        "  grouping: {:.2} GEMMs per PJRT array step (1.0 = no co-residency)",
        total_gemms as f64 / engine.exec_count() as f64
    );
    println!("e2e_serve OK");
}
