//! Offline profile tables vs the online pow-2 ladder (`mtsa profile`,
//! see `docs/profiling.md`).
//!
//! Two tenants share a 96×128 array under 2D fission.  Each layer
//! reduces over K = 1152 = 12·96: the array height divides K exactly, so
//! the profiled exact-fit tile (96 rows) folds the reduction 12 times —
//! but 96 is not a power of two, so the online ladder can never try it
//! and settles for 64-row tiles with 18 folds.  The profiler finds the
//! shape offline (closed-form pricing, no simulation); the scheduler
//! just looks it up.
//!
//! ```bash
//! cargo run --release --example profile_tables
//! ```

use mtsa::coordinator::scheduler::{
    AllocPolicy, DynamicScheduler, PartitionMode, SchedulerConfig,
};
use mtsa::profiler::{ProfileStore, ProfileTable};
use mtsa::report;
use mtsa::sim::buffers::BufferConfig;
use mtsa::sim::dataflow::ArrayGeometry;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::dnng::{Dnn, Layer, WorkloadPool};
use mtsa::workloads::shapes::{LayerKind, LayerShape};

/// A deep-reduction tenant: 3 fc layers, K = 1152 (= 12 exact folds on a
/// 96-row array, 18 ragged folds on the ladder's 64-row tile).
fn tenant(name: &str) -> Dnn {
    let layers = (0..3)
        .map(|i| {
            Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(2_000, 1_152, 384))
        })
        .collect();
    Dnn::chain(name, layers)
}

fn shapes(m: &mtsa::coordinator::RunMetrics, name: &str) -> String {
    m.partition_shapes(name)
        .iter()
        .map(|(r, c)| format!("{r}x{c}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() {
    let geom = ArrayGeometry::new(96, 128);
    let bufs = BufferConfig::default();
    let pool = WorkloadPool::new("profile-demo", vec![tenant("a"), tenant("b")]);

    // Offline step (`mtsa profile` persists this to disk; here we keep
    // it in memory): both tenants share the layer shapes, so one model's
    // table covers the whole mix.
    let table = ProfileTable::build("a", &tenant("a"), geom, &bufs);
    let store = std::sync::Arc::new(ProfileStore::from_tables("<memory>", vec![table]));

    let base = SchedulerConfig {
        geom,
        partition_mode: PartitionMode::TwoD,
        alloc_policy: AllocPolicy::EqualShare,
        ..Default::default()
    };
    let ladder = DynamicScheduler::new(base.clone()).run(&pool);
    let tabled = DynamicScheduler::new(SchedulerConfig { tables: Some(store), ..base }).run(&pool);

    println!("2-tenant mix on one 96x128 array (3 fc layers each, K = 1152):\n");
    let mut t = Table::new(&["metric", "pow-2 ladder", "profile tables", "saving"]);
    t.row(&[
        "makespan (cycles)".into(),
        ladder.makespan.to_string(),
        tabled.makespan.to_string(),
        format!(
            "{:+.1}%",
            report::saving_pct(ladder.makespan as f64, tabled.makespan as f64)
        ),
    ]);
    t.row(&[
        "mean completion (cycles)".into(),
        format!("{:.0}", report::mean_completion(&ladder)),
        format!("{:.0}", report::mean_completion(&tabled)),
        format!(
            "{:+.1}%",
            report::saving_pct(report::mean_completion(&ladder), report::mean_completion(&tabled))
        ),
    ]);
    println!("{}", t.render());

    println!("tile shapes per tenant (rows x cols, dispatch order):");
    let mut t = Table::new(&["tenant", "pow-2 ladder", "profile tables"]);
    for dnn in &pool.dnns {
        t.row(&[dnn.name.clone(), shapes(&ladder, &dnn.name), shapes(&tabled, &dnn.name)]);
    }
    println!("{}", t.render());

    println!(
        "the ladder's tallest tile is 64 rows (next power of two, 18 folds of K=1152); \
         the profiled 96-row exact fit folds only 12 times."
    );
    assert!(
        tabled.makespan < ladder.makespan,
        "profile tables must beat the pow-2 ladder on this mix ({} vs {})",
        tabled.makespan,
        ladder.makespan
    );
    assert!(
        tabled
            .dispatches
            .iter()
            .any(|d| d.tile.rows == 96),
        "the winning plan uses the profiled 96-row exact fit"
    );
}
