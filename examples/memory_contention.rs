//! Two-tenant memory interference sweep — the shared memory hierarchy
//! (`rust/src/mem`) made visible.
//!
//! Two zoo tenants (NCF recommendation + handwriting LSTM) share one
//! 128x128 array while the DRAM interface is swept from starved (8
//! words/cycle) to HBM-class (128), under all three arbitration modes.
//! For every point the table reports the makespan, per-run stall
//! fraction, achieved interface bandwidth and the deadline miss rate —
//! the interference that the isolated per-tenant DRAM bound structurally
//! cannot show.  A second table pits the MoCA-style `mem-aware` policy
//! against plain `widest` at the most contended point: serializing
//! memory-bound layers instead of processor-sharing a saturated
//! interface buys back tail latency.
//!
//! ```bash
//! cargo run --release --example memory_contention
//! ```

use mtsa::coordinator::scenario::{Scenario, ScenarioSpec};
use mtsa::coordinator::scheduler::{AllocPolicy, DynamicScheduler, SchedulerConfig};
use mtsa::mem::{ArbitrationMode, MemConfig};
use mtsa::sim::dram::DramConfig;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::generator::ArrivalProcess;
use mtsa::workloads::models;

fn cfg_with(bw: f64, arb: ArbitrationMode, policy: AllocPolicy) -> SchedulerConfig {
    SchedulerConfig {
        alloc_policy: policy,
        mem: Some(MemConfig {
            dram: DramConfig { words_per_cycle: bw, burst_latency: 100 },
            arbitration: arb,
            banks: 8,
        }),
        ..Default::default()
    }
}

fn main() {
    let templates = models::by_spec("NCF,HandwritingLSTM").expect("zoo models").dnns;
    let spec = ScenarioSpec {
        name: "mem-contention".to_string(),
        arrival: ArrivalProcess::Poisson { mean_interarrival: 20_000.0 },
        requests: 6,
        seed: 2023,
        qos_slack: Some(3.0),
    };

    println!("two-tenant interference: bandwidth x arbitration (policy = widest)");
    let mut t = Table::new(&[
        "bw (w/c)", "arb", "makespan", "stall", "achieved w/c", "refetch words", "p95 lat", "miss",
    ]);
    for &bw in &[8.0, 16.0, 32.0, 64.0, 128.0] {
        for arb in ArbitrationMode::ALL {
            let cfg = cfg_with(bw, arb, AllocPolicy::WidestToHeaviest);
            let scenario = Scenario::generate(&templates, &spec, &cfg);
            let (obs, outcome) =
                scenario.run(&mut DynamicScheduler::new(cfg.clone()), cfg.geom);
            let m = &obs.metrics;
            t.row(&[
                format!("{bw:.0}"),
                arb.tag().to_string(),
                m.makespan.to_string(),
                format!("{:.1}%", 100.0 * m.mem_total.stall_fraction()),
                format!("{:.2}", m.mem_total.achieved_words_per_cycle()),
                m.mem_total.refetch_words.to_string(),
                format!("{:.0}", outcome.overall.p95_latency),
                format!("{:.1}%", 100.0 * outcome.miss_rate()),
            ]);
        }
    }
    println!("{}", t.render());

    println!("mem-aware vs widest at the most contended point (8 w/c, fair):");
    let mut t = Table::new(&["policy", "makespan", "mean stall", "p95 lat", "p99 lat", "miss"]);
    for policy in [AllocPolicy::WidestToHeaviest, AllocPolicy::MemAware] {
        let cfg = cfg_with(8.0, ArbitrationMode::FairShare, policy);
        let scenario = Scenario::generate(&templates, &spec, &cfg);
        let (obs, outcome) = scenario.run(&mut DynamicScheduler::new(cfg.clone()), cfg.geom);
        t.row(&[
            policy.tag().to_string(),
            obs.metrics.makespan.to_string(),
            format!("{:.1}%", 100.0 * obs.metrics.mem_total.stall_fraction()),
            format!("{:.0}", outcome.overall.p95_latency),
            format!("{:.0}", outcome.overall.p99_latency),
            format!("{:.1}%", 100.0 * outcome.miss_rate()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "note: with [mem] disabled these runs collapse to today's isolated model — \
         see docs/memory.md for the semantics."
    );
}
