//! A compressed serving "day" on the fleet tier: diurnal × bursty traffic
//! streamed across a cluster of partitioned accelerators, sized from the
//! workload's own isolated timings to a ~0.8 fleet load factor.  Runs the
//! identical day twice at equal silicon — dynamically partitioned
//! instances vs the sequential-FIFO baseline — and prints the per-class
//! SLO tables.  The headline claim of the serving tier is pinned at the
//! bottom: dynamic partitioning must not lose on latency-critical SLO
//! attainment.
//!
//! ```bash
//! cargo run --release --example fleet_day [seed] [requests]
//! ```

use mtsa::coordinator::scheduler::SchedulerConfig;
use mtsa::fleet::{run_fleet, FleetConfig, FleetPolicy, FleetReport, Placement, SloClass};
use mtsa::report;
use mtsa::sim::dataflow::baseline_layer_timing;
use mtsa::workloads::generator::{ArrivalProcess, Diurnal, ModelMix};
use mtsa::workloads::models;

const INSTANCES: usize = 8;
const LOAD_FACTOR: f64 = 0.8;

/// Serving mix for the day: small recommendation/RNN models dominate,
/// with an occasional CNN.
fn day_mix() -> ModelMix {
    ModelMix::new(&[
        ("NCF", 0.40),
        ("MelodyLSTM", 0.25),
        ("HandwritingLSTM", 0.20),
        ("SA_CNN", 0.10),
        ("AlexNet", 0.05),
    ])
}

/// Mix-weighted mean isolated service time (full-array cycles) — the same
/// price the router and the deadline model use.
fn mean_isolated_cycles(mix: &ModelMix, sched: &SchedulerConfig) -> f64 {
    let mut mean = 0.0;
    for i in 0..mix.len() {
        let dnn = (models::by_name(mix.name(i)).expect("zoo model").build)();
        let iso: u64 = dnn
            .layers
            .iter()
            .map(|l| baseline_layer_timing(sched.geom, l.shape.gemm(), &sched.buffers).cycles)
            .sum();
        mean += mix.probability(i) * iso as f64;
    }
    mean
}

fn day(policy: FleetPolicy, requests: usize, seed: u64, mean_gap: f64) -> FleetConfig {
    let sched = SchedulerConfig::default();
    FleetConfig {
        instances: FleetConfig::uniform(INSTANCES, &sched, policy),
        placement: Placement::LeastLoaded,
        random_k: 2,
        classes: FleetConfig::default_classes(mean_gap),
        slots: 8,
        queue_cap: 64,
        mix: day_mix(),
        arrival: ArrivalProcess::Poisson { mean_interarrival: mean_gap },
        // One diurnal day spanning the whole trace: traffic swells to
        // 1.7x the mean at midday and sags to 0.3x overnight.
        diurnal: Some(Diurnal {
            period: requests as f64 * mean_gap,
            amplitude: 0.7,
            phase: 0.0,
        }),
        requests,
        seed,
        chunk: 4096,
        tables: None,
    }
}

fn class(r: &FleetReport, c: SloClass) -> &mtsa::fleet::ClassReport {
    r.classes.iter().find(|cr| cr.class == c).expect("all classes reported")
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(42);
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mix = day_mix();
    let sched = SchedulerConfig::default();
    let service = mean_isolated_cycles(&mix, &sched);
    // ρ = λ·S/N ⇒ mean gap = S / (N·ρ): the day runs the cluster at a
    // ~0.8 load factor whatever models the zoo prices them at.
    let mean_gap = service / (INSTANCES as f64 * LOAD_FACTOR);
    println!(
        "fleet day: {requests} requests on {INSTANCES}x 128x128, mean service {:.0} \
         cycles, mean gap {:.0} cycles (target load {LOAD_FACTOR}), seed {seed}\n",
        service, mean_gap
    );

    let dynamic = run_fleet(&day(FleetPolicy::Dynamic, requests, seed, mean_gap), threads)
        .expect("dynamic fleet");
    let sequential = run_fleet(&day(FleetPolicy::Sequential, requests, seed, mean_gap), threads)
        .expect("sequential fleet");

    println!("dynamic partitioning per instance:");
    println!("{}", report::fleet_table(&dynamic).render());
    println!("{}", report::fleet_instance_table(&dynamic).render());
    println!("sequential FIFO per instance (same silicon, same day):");
    println!("{}", report::fleet_table(&sequential).render());

    let dl = class(&dynamic, SloClass::LatencyCritical);
    let sl = class(&sequential, SloClass::LatencyCritical);
    println!(
        "\nlatency-critical: attainment {:.1}% (dynamic) vs {:.1}% (sequential), \
         p99 {} vs {} cycles",
        dl.attainment * 100.0,
        sl.attainment * 100.0,
        dl.p99,
        sl.p99,
    );
    println!(
        "fleet: util {:.1}% vs {:.1}%, cost {:.6} vs {:.6} J/query",
        dynamic.utilization * 100.0,
        sequential.utilization * 100.0,
        dynamic.cost_j_per_query,
        sequential.cost_j_per_query,
    );

    // The serving tier's pinned claim: at equal silicon, dynamically
    // partitioned instances never lose to the sequential baseline on
    // latency-critical SLO attainment.
    assert!(
        dl.attainment >= sl.attainment,
        "dynamic LC attainment {:.3} fell below sequential {:.3}",
        dl.attainment,
        sl.attainment
    );
    println!("\nok: dynamic >= sequential on latency-critical SLO attainment");
}
