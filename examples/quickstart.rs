//! Quickstart: share one 128×128 weight-stationary array between two DNNs
//! with the dynamic partitioning coordinator, and compare against the
//! single-tenant sequential baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mtsa::coordinator::baseline::SequentialBaseline;
use mtsa::coordinator::{DynamicScheduler, SchedulerConfig};
use mtsa::energy::EnergyModel;
use mtsa::report;
use mtsa::workloads::dnng::{Dnn, Layer, WorkloadPool};
use mtsa::workloads::shapes::{LayerKind, LayerShape};

fn main() {
    // 1. Describe the tenants as DNN graphs (paper §2.1).  Here: a small
    //    CNN and a narrow recommendation MLP that arrives 3k cycles in.
    let cnn = Dnn::chain(
        "mini-cnn",
        vec![
            Layer::new("conv1", LayerKind::Conv, LayerShape::conv(1, 3, 64, 64, 32, 3, 3, 1, 1)),
            Layer::new("conv2", LayerKind::Conv, LayerShape::conv(1, 32, 32, 32, 64, 3, 3, 2, 1)),
            Layer::new("fc", LayerKind::Fc, LayerShape::fc(1, 64 * 16 * 16, 10)),
        ],
    );
    let mlp = Dnn::chain(
        "reco-mlp",
        vec![
            Layer::new("mlp1", LayerKind::Fc, LayerShape::fc(64, 128, 64)),
            Layer::new("mlp2", LayerKind::Fc, LayerShape::fc(64, 64, 32)),
            Layer::new("score", LayerKind::Fc, LayerShape::fc(64, 32, 1)),
        ],
    )
    .arriving_at(3_000);
    let pool = WorkloadPool::new("quickstart", vec![cnn, mlp]);

    // 2. Run both schedulers on a TPU-like 128x128 config.
    let cfg = SchedulerConfig::default();
    let dynamic = DynamicScheduler::new(cfg.clone()).run(&pool);
    let sequential = SequentialBaseline::new(cfg.clone()).run(&pool);

    // 3. Inspect the dispatch log: which partition every layer ran on.
    println!("dynamic dispatch log:");
    for d in &dynamic.dispatches {
        println!(
            "  {:9} {:6}  cols [{:3}..{:3})  t {:>7}..{:>7}",
            d.dnn_name,
            d.layer_name,
            d.tile.col0,
            d.tile.col_end(),
            d.t_start,
            d.t_end
        );
    }

    // 4. Headline comparison.
    let model = EnergyModel::default_128();
    let e_dyn = report::total_energy(&dynamic, &model);
    let e_seq = report::total_energy(&sequential, &model);
    println!("\nmakespan: sequential {}  dynamic {}  ({:+.1}%)",
        sequential.makespan, dynamic.makespan,
        report::saving_pct(sequential.makespan as f64, dynamic.makespan as f64));
    println!("energy:   sequential {:.3} mJ  dynamic {:.3} mJ  ({:+.1}%)",
        e_seq.total_j() * 1e3, e_dyn.total_j() * 1e3,
        report::saving_pct(e_seq.total_j(), e_dyn.total_j()));
    println!("reco-mlp completion: sequential {}  dynamic {}",
        sequential.completion["reco-mlp"], dynamic.completion["reco-mlp"]);
}
