//! 2D architecture fission vs column-only partitioning — the packing win
//! rectangular tiles buy on a heavy mix (see `docs/fission.md`).
//!
//! Four tenants share a 128×128 array: one deep-reduction DNN
//! (K = 512 — it genuinely needs every PE row) and three shallow wide
//! DNNs (K = 32, M = 512 — each uses only a quarter of the rows it would
//! occupy as a column slice).  Column-only partitioning must give every
//! tenant full-height slices, so the shallow tenants serialize on the
//! width they can get; 2D fission stacks all three of them vertically in
//! the half the deep tenant leaves free, and the whole mix runs
//! concurrently.
//!
//! ```bash
//! cargo run --release --example fission_2d
//! ```

use mtsa::coordinator::scheduler::{DynamicScheduler, PartitionMode, SchedulerConfig};
use mtsa::coordinator::RunMetrics;
use mtsa::report;
use mtsa::util::tablefmt::Table;
use mtsa::workloads::dnng::{Dnn, Layer, WorkloadPool};
use mtsa::workloads::shapes::{LayerKind, LayerShape};

/// The demo mix: 1 deep-K tenant + 3 shallow-K wide-M tenants, 3 layers
/// each, all arriving at t = 0 (the paper's batch setup).
fn mix() -> WorkloadPool {
    let fc_chain = |name: &str, sr: u64, k: u64, m: u64| {
        let layers = (0..3)
            .map(|i| Layer::new(&format!("l{i}"), LayerKind::Fc, LayerShape::fc(sr, k, m)))
            .collect();
        Dnn::chain(name, layers)
    };
    WorkloadPool::new(
        "fission-demo",
        vec![
            fc_chain("deep", 4000, 512, 64),
            fc_chain("shallow-a", 4000, 32, 512),
            fc_chain("shallow-b", 4000, 32, 512),
            fc_chain("shallow-c", 4000, 32, 512),
        ],
    )
}

fn shapes(m: &RunMetrics, name: &str) -> String {
    m.partition_shapes(name)
        .iter()
        .map(|(r, c)| format!("{r}x{c}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() {
    let pool = mix();
    let columns = DynamicScheduler::new(SchedulerConfig::default()).run(&pool);
    let two_d = DynamicScheduler::new(SchedulerConfig {
        partition_mode: PartitionMode::TwoD,
        ..Default::default()
    })
    .run(&pool);

    println!("4-tenant mix on one 128x128 array (3 fc layers each, batch arrival):\n");
    let mut t = Table::new(&["metric", "columns", "2d", "saving"]);
    t.row(&[
        "makespan (cycles)".into(),
        columns.makespan.to_string(),
        two_d.makespan.to_string(),
        format!(
            "{:+.1}%",
            report::saving_pct(columns.makespan as f64, two_d.makespan as f64)
        ),
    ]);
    t.row(&[
        "mean completion (cycles)".into(),
        format!("{:.0}", report::mean_completion(&columns)),
        format!("{:.0}", report::mean_completion(&two_d)),
        format!(
            "{:+.1}%",
            report::saving_pct(report::mean_completion(&columns), report::mean_completion(&two_d))
        ),
    ]);
    println!("{}", t.render());

    println!("tile shapes per tenant (rows x cols, dispatch order):");
    let mut t = Table::new(&["tenant", "columns", "2d"]);
    for dnn in &pool.dnns {
        t.row(&[dnn.name.clone(), shapes(&columns, &dnn.name), shapes(&two_d, &dnn.name)]);
    }
    println!("{}", t.render());

    println!(
        "columns mode serializes the shallow tenants (full-height slices fight over \
         width); 2d stacks them three-high beside the deep tenant."
    );
    assert!(
        two_d.makespan < columns.makespan,
        "2D fission must beat column-only on this mix ({} vs {})",
        two_d.makespan,
        columns.makespan
    );
}
