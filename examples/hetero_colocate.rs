//! Heterogeneous co-location: run a compute-bound tenant and a
//! memory-bound tenant on one machine twice — first on the systolic
//! array alone (dynamic column partitioning splits it), then on the
//! array plus a 128-lane vector engine (intensity-aware placement
//! offloads the memory-bound tenant to the lanes and hands the whole
//! array to the compute-bound one).
//!
//! The exact cycle counts printed here are asserted in
//! `rust/tests/heterogeneous.rs` — the lane segment is the closed form
//! `startup + max(⌈MACs/lanes⌉, ⌈words/lanes⌉)` and the win is real,
//! not a rounding artifact.
//!
//! ```bash
//! cargo run --release --example hetero_colocate
//! ```

use mtsa::coordinator::{DynamicScheduler, SchedulerConfig};
use mtsa::sim::dataflow::VectorUnit;
use mtsa::workloads::dnng::{Dnn, Layer, WorkloadPool};
use mtsa::workloads::shapes::{LayerKind, LayerShape};

fn main() {
    // The canonical pair heterogeneous placement exists for: a 3×3 conv
    // with high arithmetic intensity, and an embedding lookup lowered as
    // a skinny GEMM whose intensity is far below the array's break-even.
    let conv = Dnn::chain(
        "convnet",
        vec![Layer::new(
            "conv3x3",
            LayerKind::Conv,
            LayerShape::conv(1, 64, 56, 56, 128, 3, 3, 1, 1),
        )],
    );
    let embed = Dnn::chain(
        "embedder",
        vec![Layer::new("embed", LayerKind::Embedding, LayerShape::fc(32, 1024, 64))],
    );
    let pool = WorkloadPool::new("colocate", vec![conv, embed]);
    for d in &pool.dnns {
        for l in &d.layers {
            let g = l.shape.gemm();
            println!(
                "{:9} {:8}  {:?}  intensity {:>4} macs/word  -> {:?}",
                d.name,
                l.name,
                (g.sr, g.k, g.m),
                g.intensity(),
                l.op_class(),
            );
        }
    }

    // Array alone: the planner splits the 128 columns 64/64, folding the
    // conv's 128 output columns twice; the embedding finishes early and
    // strands its slice.
    let cfg = SchedulerConfig::default();
    let array_only = DynamicScheduler::new(cfg.clone()).run(&pool);

    // Array + lanes: the embedding (memory-bound) takes all 128 lanes,
    // the conv keeps the full array.
    let hetero_cfg = SchedulerConfig { vector: Some(VectorUnit::new(128)), ..cfg };
    let hetero = DynamicScheduler::new(hetero_cfg).run(&pool);

    println!("\narray-only dispatch log:");
    for d in &array_only.dispatches {
        println!(
            "  {:9} {:8}  array cols [{:3}..{:3})  t {:>7}..{:>7}",
            d.dnn_name,
            d.layer_name,
            d.tile.col0,
            d.tile.col_end(),
            d.t_start,
            d.t_end,
        );
    }
    println!("heterogeneous dispatch log:");
    for d in &hetero.dispatches {
        let (res, lo, hi) = match d.lanes {
            Some(s) => ("lanes", s.lane0, s.end()),
            None => ("array cols", d.tile.col0, d.tile.col_end()),
        };
        println!(
            "  {:9} {:8}  {} [{:3}..{:3})  t {:>7}..{:>7}",
            d.dnn_name, d.layer_name, res, lo, hi, d.t_start, d.t_end,
        );
    }

    let saved = array_only.makespan - hetero.makespan;
    println!(
        "\nmakespan: array-only {} cycles, array+lanes {} cycles \
         ({} cycles / {:.1}% faster; {} layer(s) offloaded)",
        array_only.makespan,
        hetero.makespan,
        saved,
        100.0 * saved as f64 / array_only.makespan as f64,
        hetero.vector_dispatches,
    );
    assert!(
        hetero.makespan < array_only.makespan,
        "co-location win regressed: {} !< {}",
        hetero.makespan,
        array_only.makespan,
    );
}
